//! Harmonic-Ritz extraction of approximate eigenvectors (paper §2.3).
//!
//! After a solver run stored ℓ normalized direction/image pairs — the
//! first ℓ search directions of a (deflated) CG run, or the first ℓ
//! *block* direction columns of a rank-adaptive block-CG run
//! ([`crate::solvers::blockcg::solve_spec`]) — form `Z = [W, P]` and
//! `AZ = [AW, AP]` and solve the harmonic projection problem
//! (Morgan, 1995; paper Eq. 7):
//!
//! ```text
//!   (AZ)ᵀ (AZ u − θ Z u) = 0   ⇔   G u = θ F u,
//!   F = (AZ)ᵀ Z  (symmetric, since A is),   G = (AZ)ᵀ(AZ)  (SPD).
//! ```
//!
//! The θ are harmonic Ritz values approximating eigenvalues of `A`; the
//! recycled basis for the next system is `W' = Z U` (and `A W' = AZ·U`
//! for free). Because `P` and `AP` were stored during the CG iteration,
//! the extraction costs `O(n(k+ℓ)²)` flops and **zero extra matvecs**.

use crate::linalg::eig::gen_sym_eig;
use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::norm2;
use crate::solvers::defcg::Deflation;
use crate::solvers::StoredDirections;

/// Which end of the spectrum to keep in the recycled basis.
///
/// For the paper's GPC systems `A = I + H^½KH^½` the spectrum is bounded
/// below by 1 and heavy at the top, so deflating the **largest** harmonic
/// Ritz values (the choice visualized in the paper's Fig. 1) is the
/// default. `Smallest` matches the classic Saad-style deflation used when
/// tiny eigenvalues limit convergence.
/// `TwoSided` interleaves both ends — largest, smallest, 2nd-largest,
/// 2nd-smallest, … — so a truncated prefix attacks the condition number
/// from above and below at once (the [`crate::solvers::strategy`] layer's
/// two-sided split rule).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RitzSelect {
    Largest,
    Smallest,
    TwoSided,
}

/// Harmonic-Ritz configuration.
#[derive(Clone, Copy, Debug)]
pub struct RitzConfig {
    /// Number of approximate eigenvectors to keep (the paper's k).
    pub k: usize,
    pub select: RitzSelect,
    /// Drop Ritz vectors whose column norm collapses below this.
    pub min_col_norm: f64,
}

impl Default for RitzConfig {
    fn default() -> Self {
        RitzConfig { k: 8, select: RitzSelect::Largest, min_col_norm: 1e-10 }
    }
}

/// A single extracted pair: the harmonic Ritz value θ (≈ eigenvalue of A)
/// and the quality of the pair (relative eigenresidual estimate).
#[derive(Clone, Debug)]
pub struct RitzValue {
    pub theta: f64,
    /// Relative eigenresidual `‖AW·e_j − θ_j W·e_j‖ / (1 + |θ_j|)` of the
    /// normalized pair — small means well-converged. Budget enforcement
    /// keeps the smallest-residual pairs when truncating
    /// (residual-optimal truncation, see `RecycleBudget`).
    pub resid: f64,
}

/// A successful extraction: the built basis, the retained Ritz values,
/// and the **full ranked spectrum** — every finite harmonic Ritz value in
/// selection order, *before* truncation to `cfg.k`. The spectrum is what
/// the [`crate::solvers::strategy`] payoff evaluator sizes k against:
/// entry `j` is the θ removed by deflating the j-th ranked candidate.
#[derive(Clone, Debug)]
pub struct Extraction {
    pub defl: Deflation,
    pub vals: Vec<RitzValue>,
    pub spectrum: Vec<f64>,
}

/// Why an extraction produced no basis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExtractFailure {
    /// Nothing to extract — no stored directions or `k = 0`. Benign; not
    /// a failure of the numerics.
    Empty,
    /// Numerical failure: the generalized eigensolve rejected the Gram
    /// matrices, every candidate pair was non-finite, or every built
    /// column collapsed below `min_col_norm`. The run's panel is dropped
    /// (counted by `RecycleManager::extraction_failures`).
    Numerical,
}

/// Extract a new recycled basis from the previous deflation (may be `None`
/// on the first system) and the directions stored during the last solve —
/// single-RHS CG directions and block-CG direction panels alike (block
/// columns within one iteration are not A-conjugate to each other, only
/// across iterations; the joint MGS below absorbs that, so multi-RHS
/// traffic feeds the basis through exactly this entry point).
///
/// Returns the new `Deflation { W, AW }` plus the selected harmonic Ritz
/// values, or `None` if nothing useful could be extracted (e.g. no stored
/// directions). Thin wrapper over [`try_extract`], which additionally
/// distinguishes benign-empty from numerical failure and reports the full
/// ranked spectrum.
pub fn extract(
    prev: Option<&Deflation>,
    stored: &StoredDirections,
    n: usize,
    cfg: &RitzConfig,
) -> Option<(Deflation, Vec<RitzValue>)> {
    try_extract(prev, stored, n, cfg).ok().map(|e| (e.defl, e.vals))
}

/// [`extract`] with structured failure reporting and the ranked spectrum.
pub fn try_extract(
    prev: Option<&Deflation>,
    stored: &StoredDirections,
    n: usize,
    cfg: &RitzConfig,
) -> Result<Extraction, ExtractFailure> {
    let k_prev = prev.map(|d| d.k()).unwrap_or(0);
    // Drop non-finite stored pairs before anything touches them: a
    // near-breakdown run can record Inf/NaN direction columns, and a
    // single one poisons the Gram matrices — an Inf column even turns
    // into NaN inside the MGS normalization (‖v‖ = ∞ rescales by 0) —
    // long before any θ-level filter could catch it. The extraction
    // degrades to the surviving columns instead of panicking the caller.
    let finite: Vec<usize> = (0..stored.len())
        .filter(|&j| {
            stored.p[j].iter().all(|v| v.is_finite())
                && stored.ap[j].iter().all(|v| v.is_finite())
        })
        .collect();
    if finite.len() < stored.len() {
        crate::log_warn!(
            "dropping {} non-finite stored direction pair(s) before Ritz extraction",
            stored.len() - finite.len()
        );
    }
    let m = k_prev + finite.len();
    if m == 0 || cfg.k == 0 {
        return Err(ExtractFailure::Empty);
    }

    // Z = [W, P], AZ = [AW, AP]
    let mut z = Mat::zeros(n, m);
    let mut az = Mat::zeros(n, m);
    if let Some(d) = prev {
        for j in 0..k_prev {
            z.set_col(j, &d.w.col(j));
            az.set_col(j, &d.aw.col(j));
        }
    }
    for (dst, &j) in finite.iter().enumerate() {
        z.set_col(k_prev + dst, &stored.p[j]);
        az.set_col(k_prev + dst, &stored.ap[j]);
    }

    // Joint modified Gram–Schmidt on (Z, AZ): orthonormalize Z's columns,
    // applying the *same* column operations to AZ so AZ' = A·Z' stays
    // exact, and drop columns that collapse (stored directions nearly
    // inside span(W) — happens when consecutive systems are identical).
    // Without this, G = (AZ)ᵀ(AZ) is numerically singular and the
    // generalized eigensolve fails.
    let (z, az) = joint_mgs(&z, &az, 1e-10);
    if z.cols() == 0 {
        return Err(ExtractFailure::Numerical);
    }

    // F = (AZ)ᵀZ, G = (AZ)ᵀ(AZ). F is symmetric in exact arithmetic
    // because A is; enforce it against round-off.
    let mut f = az.t_matmul(&z);
    f.symmetrize();
    let g = {
        let mut g = az.t_matmul(&az);
        g.symmetrize();
        g
    };

    let mut pairs = match gen_sym_eig(&g, &f) {
        Ok(p) => p,
        Err(e) => {
            crate::log_warn!("harmonic Ritz extraction failed ({e}); dropping recycle basis");
            return Err(ExtractFailure::Numerical);
        }
    };
    // A non-finite pair (θ or eigenvector entries) would previously panic
    // the `partial_cmp(..).unwrap()` sort below — on the service that
    // takes down the drainer thread. Filter, then sort with the total
    // order, so a contaminated extraction degrades instead of panicking.
    pairs.retain(|(theta, u)| theta.is_finite() && u.iter().all(|v| v.is_finite()));
    if pairs.is_empty() {
        return Err(ExtractFailure::Numerical);
    }

    // gen_sym_eig returns |θ| descending. For SPD A all θ should be
    // positive; order by signed value according to the selection rule.
    match cfg.select {
        RitzSelect::Largest => pairs.sort_by(|a, b| b.0.total_cmp(&a.0)),
        RitzSelect::Smallest => pairs.sort_by(|a, b| a.0.total_cmp(&b.0)),
        RitzSelect::TwoSided => {
            pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
            pairs = interleave_ends(pairs);
        }
    }
    // The full ranked spectrum — what the strategy layer's payoff
    // evaluator sizes k against — is captured before truncation.
    let spectrum: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    pairs.truncate(cfg.k);

    // W' = Z U, AW' = AZ U as two block products (one pass over Z/AZ per
    // column panel, instead of a per-pair matvec loop), then normalize
    // columns jointly so the basis is well-scaled (scaling a column of
    // both W and AW preserves AW = A·W). `block_matvec_into` (not
    // `matmul`) keeps each output element the same `dot(row, col)` the
    // per-pair `z.matvec(u)` loop computed, so the extracted basis is
    // bit-for-bit the pre-block-migration one.
    let mut u = Mat::zeros(z.cols(), pairs.len());
    for (c, (_, uvec)) in pairs.iter().enumerate() {
        u.set_col(c, uvec);
    }
    let mut w_all = Mat::zeros(n, pairs.len());
    let mut aw_all = Mat::zeros(n, pairs.len());
    z.block_matvec_into(&u, &mut w_all);
    az.block_matvec_into(&u, &mut aw_all);
    let mut w = Mat::zeros(n, pairs.len());
    let mut aw = Mat::zeros(n, pairs.len());
    let mut vals = Vec::with_capacity(pairs.len());
    let mut dst = 0;
    for (c, (theta, _)) in pairs.iter().enumerate() {
        let wcol = w_all.col(c);
        let norm = norm2(&wcol);
        if !norm.is_finite() || norm < cfg.min_col_norm {
            continue;
        }
        let awcol = aw_all.col(c);
        let inv = 1.0 / norm;
        let wcol: Vec<f64> = wcol.iter().map(|v| v * inv).collect();
        let awcol: Vec<f64> = awcol.iter().map(|v| v * inv).collect();
        // Pair quality for residual-optimal truncation: the relative
        // eigenresidual of the normalized pair. Costs one fused pass —
        // no extra matvec (AW·e_j is already in hand).
        let mut rq = 0.0;
        for (wv, av) in wcol.iter().zip(awcol.iter()) {
            let d = av - theta * wv;
            rq += d * d;
        }
        let resid = rq.sqrt() / (1.0 + theta.abs());
        w.set_col(dst, &wcol);
        aw.set_col(dst, &awcol);
        vals.push(RitzValue { theta: *theta, resid });
        dst += 1;
    }
    if dst == 0 {
        return Err(ExtractFailure::Numerical);
    }
    // Shrink if columns were dropped.
    let (w, aw) = if dst < w.cols() {
        let mut w2 = Mat::zeros(n, dst);
        let mut aw2 = Mat::zeros(n, dst);
        for j in 0..dst {
            w2.set_col(j, &w.col(j));
            aw2.set_col(j, &aw.col(j));
        }
        (w2, aw2)
    } else {
        (w, aw)
    };

    Ok(Extraction { defl: Deflation::new(w, aw), vals, spectrum })
}

/// Interleave a descending-sorted pair list from both ends: indices
/// `[0, m−1, 1, m−2, …]`, i.e. largest, smallest, 2nd-largest, … — the
/// `RitzSelect::TwoSided` ranking.
fn interleave_ends<T>(sorted: Vec<T>) -> Vec<T> {
    let mut deque: std::collections::VecDeque<T> = sorted.into();
    let mut out = Vec::with_capacity(deque.len());
    let mut front = true;
    loop {
        let next = if front { deque.pop_front() } else { deque.pop_back() };
        match next {
            Some(v) => out.push(v),
            None => break,
        }
        front = !front;
    }
    out
}

/// Modified Gram–Schmidt on the columns of `z`, mirroring every column
/// operation onto `az` so that `az` remains the image of `z` under the
/// same linear map. Columns whose remainder drops below `tol` (relative to
/// their original norm, which is ~1 here) are dropped from both.
fn joint_mgs(z: &Mat, az: &Mat, tol: f64) -> (Mat, Mat) {
    let n = z.rows();
    let mut zc: Vec<Vec<f64>> = Vec::new();
    let mut azc: Vec<Vec<f64>> = Vec::new();
    for j in 0..z.cols() {
        let mut v = z.col(j);
        let mut av = az.col(j);
        // Two MGS passes for robustness.
        for _ in 0..2 {
            for (q, aq) in zc.iter().zip(azc.iter()) {
                let c = crate::linalg::vec_ops::dot(q, &v);
                if c != 0.0 {
                    crate::linalg::vec_ops::axpy(-c, q, &mut v);
                    crate::linalg::vec_ops::axpy(-c, aq, &mut av);
                }
            }
        }
        let nv = norm2(&v);
        if nv > tol {
            let inv = 1.0 / nv;
            crate::linalg::vec_ops::scale(&mut v, inv);
            crate::linalg::vec_ops::scale(&mut av, inv);
            zc.push(v);
            azc.push(av);
        }
    }
    let m = zc.len();
    let mut zo = Mat::zeros(n, m);
    let mut azo = Mat::zeros(n, m);
    for (j, (v, av)) in zc.iter().zip(azc.iter()).enumerate() {
        zo.set_col(j, v);
        azo.set_col(j, av);
    }
    (zo, azo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig::sym_eig;
    use crate::linalg::mat::Mat;
    use crate::solvers::cg::{self, CgConfig};
    use crate::solvers::DenseOp;
    use crate::util::rng::Rng;

    /// Run CG with storage on a random SPD system and extract Ritz pairs.
    fn run_and_extract(a: &Mat, l: usize, k: usize, select: RitzSelect) -> (Deflation, Vec<RitzValue>) {
        let n = a.rows();
        let b: Vec<f64> = (0..n).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let cfg = CgConfig { tol: 1e-12, max_iters: 0, store_l: l, ..Default::default() };
        let r = cg::solve(&DenseOp::new(a), &b, None, &cfg);
        assert!(r.stored.len() >= l.min(r.iterations));
        extract(None, &r.stored, n, &RitzConfig { k, select, min_col_norm: 1e-12 }).unwrap()
    }

    #[test]
    fn ritz_values_bracket_spectrum() {
        // All harmonic Ritz values must lie within [λ_min, λ_max] of A
        // (up to round-off) — they are Rayleigh-quotient-like quantities.
        let mut rng = Rng::new(1);
        let a = Mat::rand_spd(40, 1e4, &mut rng);
        let eig = sym_eig(&a).unwrap();
        let (lam_min, lam_max) = (eig.values[0], eig.values[39]);
        let (_, vals) = run_and_extract(&a, 12, 8, RitzSelect::Largest);
        for v in &vals {
            assert!(
                v.theta >= lam_min * 0.9 && v.theta <= lam_max * 1.1,
                "θ = {} outside [{lam_min}, {lam_max}]",
                v.theta
            );
        }
    }

    #[test]
    fn largest_ritz_approximates_top_eigenvalue() {
        // CG's Krylov space finds extremal eigenvalues fast; after 12
        // stored iterations the top harmonic Ritz value should approximate
        // λ_max well for a matrix with spread-out spectrum.
        let mut rng = Rng::new(2);
        let a = Mat::rand_spd(60, 1e5, &mut rng);
        let eig = sym_eig(&a).unwrap();
        let lam_max = eig.values[59];
        let (_, vals) = run_and_extract(&a, 14, 4, RitzSelect::Largest);
        let top = vals.iter().map(|v| v.theta).fold(f64::MIN, f64::max);
        assert!(
            (top - lam_max).abs() / lam_max < 0.05,
            "top Ritz {top} vs λ_max {lam_max}"
        );
    }

    #[test]
    fn extracted_basis_has_consistent_aw() {
        // AW must equal A·W — the extraction gets AW for free from AZ, and
        // the two must agree.
        let mut rng = Rng::new(3);
        let a = Mat::rand_spd(30, 1e3, &mut rng);
        let (defl, _) = run_and_extract(&a, 10, 5, RitzSelect::Largest);
        let want = a.matmul(&defl.w);
        assert!(
            defl.aw.max_abs_diff(&want) < 1e-8,
            "AW inconsistent: {}",
            defl.aw.max_abs_diff(&want)
        );
    }

    #[test]
    fn selection_rules_differ() {
        let mut rng = Rng::new(4);
        let a = Mat::rand_spd(50, 1e4, &mut rng);
        let (_, big) = run_and_extract(&a, 12, 3, RitzSelect::Largest);
        let (_, small) = run_and_extract(&a, 12, 3, RitzSelect::Smallest);
        let min_big = big.iter().map(|v| v.theta).fold(f64::MAX, f64::min);
        let max_small = small.iter().map(|v| v.theta).fold(f64::MIN, f64::max);
        assert!(min_big > max_small);
    }

    #[test]
    fn empty_inputs_return_none() {
        let stored = StoredDirections::default();
        assert!(extract(None, &stored, 10, &RitzConfig::default()).is_none());
        let cfg = RitzConfig { k: 0, ..Default::default() };
        assert!(extract(None, &stored, 10, &cfg).is_none());
    }

    #[test]
    fn two_sided_interleaves_extremes() {
        let mut rng = Rng::new(9);
        let a = Mat::rand_spd(50, 1e4, &mut rng);
        let (_, vals) = run_and_extract(&a, 14, 6, RitzSelect::TwoSided);
        assert!(vals.len() >= 4);
        // Rank order: largest first, then smallest, and the two leading
        // entries bracket everything behind them.
        assert!(vals[0].theta > vals[1].theta);
        for v in &vals[2..] {
            assert!(
                vals[1].theta <= v.theta && v.theta <= vals[0].theta,
                "θ = {} outside [{}, {}]",
                v.theta,
                vals[1].theta,
                vals[0].theta
            );
        }
    }

    #[test]
    fn try_extract_reports_spectrum_and_failure_kinds() {
        // Benign empty: no stored directions at all.
        let stored = StoredDirections::default();
        assert_eq!(
            try_extract(None, &stored, 10, &RitzConfig::default()).unwrap_err(),
            ExtractFailure::Empty
        );
        // Numerical: a degenerate panel whose AP image is zero makes
        // G = (AZ)ᵀ(AZ) singular and the generalized eigensolve fails.
        let n = 8;
        let mut e1 = vec![0.0; n];
        e1[0] = 1.0;
        let degenerate = StoredDirections { p: vec![e1], ap: vec![vec![0.0; n]] };
        assert_eq!(
            try_extract(None, &degenerate, n, &RitzConfig::default()).unwrap_err(),
            ExtractFailure::Numerical
        );
        // Success: the spectrum holds every ranked candidate (≥ the
        // truncated basis) in selection order.
        let mut rng = Rng::new(10);
        let a = Mat::rand_spd(30, 1e3, &mut rng);
        let b: Vec<f64> = (0..30).map(|i| 1.0 + (i % 5) as f64).collect();
        let cfg = CgConfig { tol: 1e-12, max_iters: 0, store_l: 10, ..Default::default() };
        let r = cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let ext = try_extract(
            None,
            &r.stored,
            30,
            &RitzConfig { k: 3, select: RitzSelect::Largest, min_col_norm: 1e-12 },
        )
        .unwrap();
        assert!(ext.defl.k() <= 3);
        assert!(ext.spectrum.len() >= ext.vals.len());
        for w in ext.spectrum.windows(2) {
            assert!(w[0] >= w[1], "largest-first ranking violated: {:?}", ext.spectrum);
        }
        assert_eq!(ext.vals[0].theta, ext.spectrum[0]);
    }

    #[test]
    fn chains_with_previous_deflation() {
        // Extraction with a previous basis must produce a basis of size
        // ≤ k and keep AW consistent.
        let mut rng = Rng::new(5);
        let a = Mat::rand_spd(35, 1e4, &mut rng);
        let (d1, _) = run_and_extract(&a, 8, 4, RitzSelect::Largest);
        // Second solve, deflated, then extract with prev = d1.
        let b: Vec<f64> = (0..35).map(|i| (i as f64).cos()).collect();
        let cfg = CgConfig { tol: 1e-12, max_iters: 0, store_l: 8, ..Default::default() };
        let r = crate::solvers::defcg::solve(&DenseOp::new(&a), &b, None, Some(&d1), &cfg);
        let (d2, vals) = extract(
            Some(&d1),
            &r.stored,
            35,
            &RitzConfig { k: 4, select: RitzSelect::Largest, min_col_norm: 1e-12 },
        )
        .unwrap();
        assert!(d2.k() <= 4);
        assert_eq!(vals.len(), d2.k());
        let want = a.matmul(&d2.w);
        assert!(d2.aw.max_abs_diff(&want) < 1e-7);
    }

    #[test]
    fn nan_contaminated_panel_degrades_instead_of_panicking() {
        // A near-breakdown run can hand the extraction Inf/NaN direction
        // columns. Before the total_cmp/filter fix this panicked in the
        // selection sort (`partial_cmp(..).unwrap()`) — on the service
        // that killed the drainer thread. Now the poisoned columns are
        // dropped and the surviving ones still produce a usable basis.
        let mut rng = Rng::new(7);
        let n = 40;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let cfg = CgConfig { tol: 1e-10, max_iters: 0, store_l: 10, ..Default::default() };
        let r = cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let mut stored = r.stored.clone();
        assert!(stored.len() >= 6);
        // Poison three pairs three different ways: NaN in p, NaN in Ap,
        // and an all-Inf direction (the case that turns into NaN inside
        // MGS normalization if not filtered up front).
        stored.p[0][n / 2] = f64::NAN;
        stored.ap[2][0] = f64::NAN;
        for v in stored.p[4].iter_mut() {
            *v = f64::INFINITY;
        }
        let ritz_cfg = RitzConfig { k: 6, select: RitzSelect::Largest, min_col_norm: 1e-12 };
        let (defl, vals) = extract(None, &stored, n, &ritz_cfg)
            .expect("surviving columns must still yield a basis");
        assert!(defl.k() > 0 && defl.k() <= 6);
        assert_eq!(vals.len(), defl.k());
        for v in &vals {
            assert!(v.theta.is_finite(), "selected θ must be finite");
            assert!(v.resid.is_finite() && v.resid >= 0.0);
        }
        // The degraded basis is still numerically consistent: AW == A·W.
        let want = a.matmul(&defl.w);
        assert!(defl.aw.max_abs_diff(&want) < 1e-7);
        // Smallest-selection path takes the other sort branch.
        let small_cfg = RitzConfig { k: 3, select: RitzSelect::Smallest, min_col_norm: 1e-12 };
        let (_, small) = extract(None, &stored, n, &small_cfg).unwrap();
        assert!(small.iter().all(|v| v.theta.is_finite()));
    }

    #[test]
    fn resid_flags_converged_pairs() {
        // The eigenresidual must be small for a pair CG has converged
        // (the top of the spectrum after many iterations) and must be
        // monotone evidence: a fully resolved invariant subspace has
        // resid ≈ 0 while a half-baked one does not.
        let mut rng = Rng::new(8);
        let a = Mat::rand_spd(50, 1e5, &mut rng);
        let (_, vals) = run_and_extract(&a, 14, 4, RitzSelect::Largest);
        let best = vals.iter().map(|v| v.resid).fold(f64::MAX, f64::min);
        assert!(best < 1e-3, "best pair should be well-converged, resid = {best}");
        for v in &vals {
            assert!(v.resid.is_finite() && v.resid >= 0.0);
        }
    }

    #[test]
    fn deflation_with_extracted_basis_reduces_iterations() {
        // The end-to-end property the paper sells: recycle from system 1
        // to an identical system 2 and converge in fewer iterations.
        let mut rng = Rng::new(6);
        let n = 100;
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let cfg = CgConfig { tol: 1e-8, max_iters: 0, store_l: 12, ..Default::default() };
        let r1 = cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let (defl, _) = extract(
            None,
            &r1.stored,
            n,
            &RitzConfig { k: 8, select: RitzSelect::Largest, min_col_norm: 1e-12 },
        )
        .unwrap();
        let b2: Vec<f64> = (0..n).map(|i| 2.0 - (i % 3) as f64).collect();
        let plain = cg::solve(&DenseOp::new(&a), &b2, None, &cfg);
        let defl_run =
            crate::solvers::defcg::solve(&DenseOp::new(&a), &b2, None, Some(&defl), &cfg);
        assert!(
            defl_run.iterations < plain.iterations,
            "deflated {} >= plain {}",
            defl_run.iterations,
            plain.iterations
        );
    }
}
