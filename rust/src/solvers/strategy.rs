//! Pluggable recycle-space strategies with predictive adaptive-k sizing.
//!
//! The selection layer is the third pluggable axis of a recycled solve,
//! alongside preconditioning and memory budgets. A [`RecycleStrategy`]
//! answers the two questions the recycle pipeline has so far hard-coded:
//!
//! 1. **Which end of the spectrum do we keep?** ([`RecycleStrategy::ordering`]
//!    maps onto a [`RitzSelect`] ranking used by harmonic-Ritz extraction.)
//! 2. **How many candidates actually pay for themselves?**
//!    ([`RecycleStrategy::choose_k`] — a predicted-payoff evaluation over
//!    the ranked Ritz spectrum.)
//!
//! Three fixed rules ship with the crate — [`HarmonicLargest`] (the
//! historical default, bitwise-pinned), [`RitzSmallest`], and
//! [`TwoSidedSplit`] — plus [`AdaptiveK`], which sizes k per sequence
//! from the CG κ-bound payoff model below and shrinks to k = 0 (plain
//! CG) when recycling cannot pay.
//!
//! # The κ-bound payoff model
//!
//! The classical CG error bound gives the iterations to reach a relative
//! tolerance `tol` on a spectrum of condition number κ:
//!
//! ```text
//! N(κ, tol) = ⌈ ln(2/tol) / ln(1/ρ) ⌉,   ρ = (√κ − 1) / (√κ + 1)
//! ```
//!
//! Deflating the first `j` ranked Ritz values removes them from the
//! effective spectrum, so the evaluator scores retaining `j` candidates
//! as `N(κ_j, tol)` where κ_j is the condition number of the *remaining*
//! ranked spectrum. Against that saving it bills the deflation costs in
//! matvec equivalents: the O(n·j) per-iteration projection (measured via
//! [`measure_projection_col_seconds`] when timing is available, a flop
//! model otherwise) and, under `AwPolicy::Refresh`, the `j` operator
//! applications that re-form AW each system. [`best_k`] takes the argmin
//! over the *admissible* `j = 0..=k_cap`; ties go to the smaller basis.
//!
//! The spectrum the evaluator sees is the *observed* harmonic-Ritz
//! spectrum, not the true eigenvalues — a sparse sample that says nothing
//! about spectral density between its entries. Trusting it blindly would
//! let the model "deflate away" a whole cluster a few Ritz vectors at a
//! time and predict κ → 1, which no finite basis delivers. [`best_k`]
//! therefore only credits deflation at **cluster boundaries**: a cut
//! after the first `j` ranked values is admissible only when the ratio
//! across the cut is at least [`CLUSTER_GAP`] — peeling whole, separated
//! outlier groups counts, peeling into a cluster does not. On a flat
//! spectrum no cut is admissible and the adaptive rule degrades to plain
//! CG; on an outlier spectrum the argmin lands exactly at the gap.

use crate::linalg::Mat;
use crate::solvers::ritz::RitzSelect;
use crate::util::precision::to_f64;
use std::fmt;
use std::sync::Arc;

/// Everything the payoff evaluator knows about the solve environment.
#[derive(Clone, Copy, Debug)]
pub struct EvalContext {
    /// Problem dimension (rows of the operator).
    pub n: usize,
    /// Convergence tolerance the sequence solves to.
    pub tol: f64,
    /// Hard ceiling on the chosen k: the post-budget candidate count
    /// (never above `RecycleBudget::basis_cols`, so any strategy's
    /// choice composes with the memory budget by construction).
    pub k_cap: usize,
    /// Whether the AW panel is re-formed each system (`AwPolicy::Refresh`)
    /// — if so every retained column bills one matvec per solve.
    pub refresh: bool,
    /// Measured seconds per operator application from the run that
    /// produced the candidate panel, when available.
    pub matvec_seconds: Option<f64>,
    /// Measured seconds per basis column of one deflation projection
    /// (see [`measure_projection_col_seconds`]), when available.
    pub proj_col_seconds: Option<f64>,
}

/// A strategy's sizing verdict: the chosen k plus the model terms behind
/// it, all in units of iterations / matvec equivalents.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KChoice {
    /// Number of leading ranked candidates to retain.
    pub k: usize,
    /// κ-bound iteration prediction at k = 0 (no deflation).
    pub plain_iters: f64,
    /// κ-bound iteration prediction with the first `k` candidates deflated.
    pub deflated_iters: f64,
    /// Per-solve deflation overhead in matvec equivalents (projection
    /// work across the predicted iterations plus any AW refresh).
    pub overhead: f64,
}

impl KChoice {
    /// Net predicted iteration savings of this choice over plain CG.
    pub fn predicted_savings(&self) -> f64 {
        self.plain_iters - self.deflated_iters - self.overhead
    }
}

/// The decision record a [`crate::solvers::recycle::RecycleManager`] keeps
/// from its most recent absorb, surfaced through `SolveReport` and the
/// coordinator metrics so mis-sized bases are auditable.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StrategyDecision {
    /// Name of the strategy that made the call (empty before the first
    /// extraction of a sequence).
    pub strategy: &'static str,
    /// Candidates offered to the strategy (post budget truncation).
    pub k_offered: usize,
    /// Candidates the strategy retained (`0` = fall back to plain CG).
    pub k_chosen: usize,
    /// κ-bound iteration prediction without deflation.
    pub predicted_plain_iters: f64,
    /// κ-bound iteration prediction with the retained basis.
    pub predicted_deflated_iters: f64,
    /// Predicted per-solve overhead of the retained basis (matvec
    /// equivalents).
    pub predicted_overhead: f64,
}

impl StrategyDecision {
    /// Net predicted iteration savings of the recorded choice.
    pub fn predicted_savings(&self) -> f64 {
        self.predicted_plain_iters - self.predicted_deflated_iters - self.predicted_overhead
    }
}

/// A recycle-space selection rule: which spectral end extraction should
/// rank for, and how many of the ranked candidates to retain.
///
/// Contract: `choose_k` receives the **full ranked Ritz spectrum** in the
/// strategy's own selection order (best candidate first, as produced by
/// [`RitzSelect`]) and must return a choice with `k ≤ ctx.k_cap`; the
/// manager clamps anyway, so a misbehaving strategy can never exceed the
/// memory budget. Retaining `k` means keeping the *leading* `k` ranked
/// candidates — prefix selection keeps the default fixed-k path bitwise
/// identical to the historical behavior.
pub trait RecycleStrategy: Send + Sync {
    /// Short stable name for reports and metrics.
    fn name(&self) -> &'static str;
    /// The spectral ordering harmonic-Ritz extraction ranks candidates by.
    fn ordering(&self) -> RitzSelect;
    /// Choose how many leading ranked candidates to retain.
    fn choose_k(&self, spectrum: &[f64], ctx: &EvalContext) -> KChoice;
    /// Whether the manager should time a projection pass
    /// ([`measure_projection_col_seconds`]) before calling `choose_k`.
    /// Defaults to `false` so fixed rules stay measurement-free.
    fn wants_measurement(&self) -> bool {
        false
    }
}

/// κ-bound CG iteration estimate `N(κ, tol)`; κ ≤ 1 (or non-finite)
/// collapses to a single iteration.
pub fn cg_kappa_iters(kappa: f64, tol: f64) -> f64 {
    if !kappa.is_finite() || kappa <= 1.0 {
        return 1.0;
    }
    let rho = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
    if rho <= 0.0 {
        return 1.0;
    }
    let t = tol.clamp(1e-300, 0.5);
    ((2.0 / t).ln() / (1.0 / rho).ln()).ceil().max(1.0)
}

/// Condition number of the ranked spectrum with its first `skip` entries
/// deflated away: max/min over the positive finite tail. `None` when the
/// tail holds nothing usable.
pub fn remaining_kappa(spectrum: &[f64], skip: usize) -> Option<f64> {
    let mut lo = f64::INFINITY;
    let mut hi = 0.0f64;
    for &t in spectrum.iter().skip(skip) {
        if t.is_finite() && t > 0.0 {
            lo = lo.min(t);
            hi = hi.max(t);
        }
    }
    if hi > 0.0 && lo.is_finite() {
        Some(hi / lo)
    } else {
        None
    }
}

/// Per-iteration overhead of deflating against a `j`-column basis, as a
/// fraction of one matvec. Uses the measured projection/matvec timings
/// when both are present; otherwise the flop model — one deflation
/// projection is ~4nj flops ((AW)ᵀr plus W·μ, 2nj each) against the 2n²
/// of a dense matvec, i.e. 2j/n.
pub fn projection_overhead_frac(j: usize, ctx: &EvalContext) -> f64 {
    if j == 0 {
        return 0.0;
    }
    match (ctx.matvec_seconds, ctx.proj_col_seconds) {
        (Some(mv), Some(pc)) if mv > 0.0 && pc > 0.0 && mv.is_finite() && pc.is_finite() => {
            to_f64(j) * pc / mv
        }
        _ => 2.0 * to_f64(j) / to_f64(ctx.n.max(1)),
    }
}

/// Score retaining the leading `j` ranked candidates: predicted plain and
/// deflated iteration counts plus the per-solve overhead bill.
pub fn evaluate_k(spectrum: &[f64], j: usize, ctx: &EvalContext) -> KChoice {
    let plain = remaining_kappa(spectrum, 0)
        .map(|k| cg_kappa_iters(k, ctx.tol))
        .unwrap_or(1.0);
    let deflated = remaining_kappa(spectrum, j)
        .map(|k| cg_kappa_iters(k, ctx.tol))
        .unwrap_or(1.0);
    let refresh = if ctx.refresh { to_f64(j) } else { 0.0 };
    KChoice {
        k: j,
        plain_iters: plain,
        deflated_iters: deflated,
        overhead: deflated * projection_overhead_frac(j, ctx) + refresh,
    }
}

fn total_cost(c: &KChoice) -> f64 {
    c.deflated_iters + c.overhead
}

/// Minimum ratio across a cut in the ranked Ritz spectrum for the cut to
/// count as a cluster boundary. The Ritz values are a sparse sample of
/// the true spectrum: inside a cluster they under-represent the density,
/// so deflating part of one earns no κ credit — only peeling a whole,
/// separated group (outliers a gap away from the rest) does.
pub const CLUSTER_GAP: f64 = 4.0;

/// Whether cutting the ranked spectrum after its first `j` entries lands
/// on a cluster boundary. `j = 0` (keep nothing deflated) is always
/// admissible; `j = len` (deflate the entire observed sample) never is —
/// the tail κ estimate would be vacuous.
fn cluster_boundary(spectrum: &[f64], j: usize) -> bool {
    if j == 0 {
        return true;
    }
    if j >= spectrum.len() {
        return false;
    }
    let (a, b) = (spectrum[j - 1], spectrum[j]);
    if !(a.is_finite() && b.is_finite() && a > 0.0 && b > 0.0) {
        return false;
    }
    a.max(b) / a.min(b) >= CLUSTER_GAP
}

/// Argmin of predicted total cost over the admissible `j = 0..=k_cap` —
/// cuts must land on a cluster boundary (see [`CLUSTER_GAP`]); ties go to
/// the smaller basis. A flat spectrum admits no cut and yields k = 0; an
/// outlier spectrum is peeled exactly down to the gap.
pub fn best_k(spectrum: &[f64], ctx: &EvalContext) -> KChoice {
    let cap = ctx.k_cap.min(spectrum.len());
    let mut best = evaluate_k(spectrum, 0, ctx);
    for j in 1..=cap {
        if !cluster_boundary(spectrum, j) {
            continue;
        }
        let c = evaluate_k(spectrum, j, ctx);
        if total_cost(&c) < total_cost(&best) {
            best = c;
        }
    }
    best
}

/// Time one deflation projection against the basis `(W, AW)` — the
/// per-iteration skinny products `(AW)ᵀr` and `W·μ` — and return seconds
/// **per basis column**, or `None` when the basis is empty or the clock
/// resolution defeats the measurement. The triangular `k×k` solve is
/// deliberately excluded: it is O(k²) against the O(nk) products.
pub fn measure_projection_col_seconds(w: &Mat, aw: &Mat) -> Option<f64> {
    let n = w.rows();
    let k = w.cols();
    if n == 0 || k == 0 || aw.rows() != n || aw.cols() != k {
        return None;
    }
    let mut rm = Mat::zeros(n, 1);
    let r: Vec<f64> = (0..n).map(|i| 1.0 + to_f64(i % 3)).collect();
    rm.set_col(0, &r);
    const REPS: usize = 3;
    let t0 = std::time::Instant::now();
    let mut sink = 0.0;
    for _ in 0..REPS {
        let mu = aw.t_matmul(&rm); // (AW)ᵀ r : k×1
        let back = w.matmul(&mu); // W μ : n×1
        sink += back[(0, 0)];
    }
    std::hint::black_box(sink);
    let per_col = t0.elapsed().as_secs_f64() / to_f64(REPS * k);
    (per_col.is_finite() && per_col > 0.0).then_some(per_col)
}

/// Today's behavior: rank harmonic-Ritz values largest-first and keep the
/// full offered basis. The default, bitwise-pinned path.
#[derive(Clone, Copy, Debug, Default)]
pub struct HarmonicLargest;

impl RecycleStrategy for HarmonicLargest {
    fn name(&self) -> &'static str {
        "harmonic-largest"
    }
    fn ordering(&self) -> RitzSelect {
        RitzSelect::Largest
    }
    fn choose_k(&self, spectrum: &[f64], ctx: &EvalContext) -> KChoice {
        evaluate_k(spectrum, ctx.k_cap.min(spectrum.len()), ctx)
    }
}

/// Rank Ritz values smallest-first and keep the full offered basis — the
/// right end when the spectrum has a cluster of small outliers dragging
/// κ up from below.
#[derive(Clone, Copy, Debug, Default)]
pub struct RitzSmallest;

impl RecycleStrategy for RitzSmallest {
    fn name(&self) -> &'static str {
        "ritz-smallest"
    }
    fn ordering(&self) -> RitzSelect {
        RitzSelect::Smallest
    }
    fn choose_k(&self, spectrum: &[f64], ctx: &EvalContext) -> KChoice {
        evaluate_k(spectrum, ctx.k_cap.min(spectrum.len()), ctx)
    }
}

/// Two-sided split: interleave the largest and smallest ranked values
/// (largest, smallest, 2nd-largest, 2nd-smallest, …) so a retained prefix
/// attacks κ from both ends — for spectra with outliers above *and* below
/// the bulk.
#[derive(Clone, Copy, Debug, Default)]
pub struct TwoSidedSplit;

impl RecycleStrategy for TwoSidedSplit {
    fn name(&self) -> &'static str {
        "two-sided"
    }
    fn ordering(&self) -> RitzSelect {
        RitzSelect::TwoSided
    }
    fn choose_k(&self, spectrum: &[f64], ctx: &EvalContext) -> KChoice {
        evaluate_k(spectrum, ctx.k_cap.min(spectrum.len()), ctx)
    }
}

/// Predictive adaptive sizing: harmonic-largest ordering, k chosen by
/// [`best_k`] — shrinks to k = 0 (plain CG) whenever the κ-bound savings
/// cannot beat the measured projection + refresh overhead.
#[derive(Clone, Copy, Debug, Default)]
pub struct AdaptiveK;

impl RecycleStrategy for AdaptiveK {
    fn name(&self) -> &'static str {
        "adaptive-k"
    }
    fn ordering(&self) -> RitzSelect {
        RitzSelect::Largest
    }
    fn choose_k(&self, spectrum: &[f64], ctx: &EvalContext) -> KChoice {
        best_k(spectrum, ctx)
    }
    fn wants_measurement(&self) -> bool {
        true
    }
}

/// Cloneable, comparable handle to a strategy — what `RecycleConfig` and
/// `SolveSpec` actually carry. The built-in variants resolve to
/// zero-sized statics; `Custom` carries a user implementation and
/// compares by pointer identity (so request coalescing stays sound).
#[derive(Clone, Default)]
pub enum StrategyChoice {
    /// [`HarmonicLargest`] — the default.
    #[default]
    HarmonicLargest,
    /// [`RitzSmallest`].
    RitzSmallest,
    /// [`TwoSidedSplit`].
    TwoSided,
    /// [`AdaptiveK`] predictive sizing.
    Auto,
    /// A user-supplied strategy.
    Custom(Arc<dyn RecycleStrategy>),
}

impl StrategyChoice {
    /// Borrow the concrete strategy behind this choice.
    pub fn resolve(&self) -> &dyn RecycleStrategy {
        match self {
            StrategyChoice::HarmonicLargest => &HarmonicLargest,
            StrategyChoice::RitzSmallest => &RitzSmallest,
            StrategyChoice::TwoSided => &TwoSidedSplit,
            StrategyChoice::Auto => &AdaptiveK,
            StrategyChoice::Custom(s) => s.as_ref(),
        }
    }
}

impl fmt::Debug for StrategyChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "StrategyChoice({})", self.resolve().name())
    }
}

impl PartialEq for StrategyChoice {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (StrategyChoice::Custom(a), StrategyChoice::Custom(b)) => Arc::ptr_eq(a, b),
            (a, b) => std::mem::discriminant(a) == std::mem::discriminant(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(n: usize, k_cap: usize) -> EvalContext {
        EvalContext {
            n,
            tol: 1e-8,
            k_cap,
            refresh: true,
            matvec_seconds: None,
            proj_col_seconds: None,
        }
    }

    #[test]
    fn kappa_bound_is_monotone_and_flat_is_one_iteration() {
        assert_eq!(cg_kappa_iters(1.0, 1e-8), 1.0);
        assert_eq!(cg_kappa_iters(0.5, 1e-8), 1.0);
        assert_eq!(cg_kappa_iters(f64::NAN, 1e-8), 1.0);
        let n10 = cg_kappa_iters(10.0, 1e-8);
        let n100 = cg_kappa_iters(100.0, 1e-8);
        let n1e4 = cg_kappa_iters(1e4, 1e-8);
        assert!(n10 < n100 && n100 < n1e4, "{n10} {n100} {n1e4}");
        // Tighter tolerance costs more iterations.
        assert!(cg_kappa_iters(100.0, 1e-12) > cg_kappa_iters(100.0, 1e-4));
    }

    #[test]
    fn remaining_kappa_scans_the_ranked_tail() {
        let spec = [1e4, 1e3, 1.5, 1.0];
        assert_eq!(remaining_kappa(&spec, 0), Some(1e4));
        assert_eq!(remaining_kappa(&spec, 1), Some(1e3));
        assert_eq!(remaining_kappa(&spec, 2), Some(1.5));
        assert_eq!(remaining_kappa(&spec, 3), Some(1.0));
        assert_eq!(remaining_kappa(&spec, 4), None);
        // Non-finite and non-positive entries are ignored.
        assert_eq!(remaining_kappa(&[f64::NAN, -2.0, 0.0, 4.0, 2.0], 0), Some(2.0));
        assert_eq!(remaining_kappa(&[f64::NAN, 0.0], 0), None);
    }

    #[test]
    fn flat_spectrum_drives_adaptive_k_to_zero() {
        // Everything clustered: no deflation subset can beat its own cost.
        let spec = vec![1.05, 1.04, 1.03, 1.02, 1.01, 1.0];
        let choice = best_k(&spec, &ctx(100, 6));
        assert_eq!(choice.k, 0, "flat spectrum must shrink to plain CG: {choice:?}");
        assert!(choice.predicted_savings() <= 0.0 || choice.k == 0);
    }

    #[test]
    fn outlier_spectrum_pays_for_deflation_and_respects_the_cap() {
        // Three heavy outliers over a tight bulk: deflating them is a
        // huge κ-bound win, deflating into the bulk is not.
        let spec = [1e4, 3e3, 1e3, 1.5, 1.4, 1.3, 1.2, 1.1, 1.05, 1.0];
        let c = best_k(&spec, &ctx(192, 8));
        assert!(c.k >= 3, "should deflate all outliers, chose {}", c.k);
        assert!(c.k <= 5, "should not chase the bulk, chose {}", c.k);
        assert!(c.predicted_savings() > 0.0);
        // A tighter cap binds the choice — and with every cut inside the
        // outlier group ruled inadmissible, the model refuses entirely.
        let capped = best_k(&spec, &ctx(192, 2));
        assert!(capped.k <= 2);
    }

    #[test]
    fn deflation_is_only_credited_at_cluster_boundaries() {
        // Same outlier group: the only admissible cut is after the whole
        // group (j = 3) — never partway through it or into the bulk.
        let spec = [1e4, 3e3, 1e3, 1.5, 1.4, 1.3, 1.2, 1.1, 1.05, 1.0];
        assert_eq!(best_k(&spec, &ctx(192, 8)).k, 3);
        // A smooth geometric decay with every adjacent ratio below
        // CLUSTER_GAP has no boundary: the sample cannot justify any cut.
        let smooth: Vec<f64> = (0..8).rev().map(|i| 3.0f64.powi(i)).collect();
        assert_eq!(best_k(&smooth, &ctx(192, 8)).k, 0);
        // One isolated outlier over a single bulk sample is still peeled.
        assert_eq!(best_k(&[1e4, 1.0], &ctx(64, 4)).k, 1);
        // Deflating the entire observed sample is never admissible, even
        // when the cap allows it (the tail κ estimate would be vacuous).
        assert_eq!(best_k(&[1e4, 3e3], &ctx(64, 8)).k, 0);
    }

    #[test]
    fn fixed_strategies_take_the_full_offer_with_their_own_ordering() {
        let spec = [9.0, 5.0, 2.0, 1.0];
        let c = ctx(64, 3);
        for (s, ord) in [
            (&HarmonicLargest as &dyn RecycleStrategy, RitzSelect::Largest),
            (&RitzSmallest, RitzSelect::Smallest),
            (&TwoSidedSplit, RitzSelect::TwoSided),
        ] {
            assert_eq!(s.ordering(), ord);
            assert_eq!(s.choose_k(&spec, &c).k, 3, "{} must take the cap", s.name());
            assert!(!s.wants_measurement());
        }
        assert_eq!(AdaptiveK.ordering(), RitzSelect::Largest);
        assert!(AdaptiveK.wants_measurement());
    }

    #[test]
    fn measured_overhead_overrides_the_flop_model() {
        let mut c = ctx(100, 4);
        // Flop fallback: 2j/n.
        assert!((projection_overhead_frac(5, &c) - 0.1).abs() < 1e-12);
        c.matvec_seconds = Some(1e-3);
        c.proj_col_seconds = Some(1e-4);
        assert!((projection_overhead_frac(5, &c) - 0.5).abs() < 1e-12);
        assert_eq!(projection_overhead_frac(0, &c), 0.0);
    }

    #[test]
    fn projection_measurement_returns_positive_seconds() {
        let mut rng = crate::util::rng::Rng::new(11);
        let w = Mat::randn(64, 4, &mut rng);
        let aw = Mat::randn(64, 4, &mut rng);
        let s = measure_projection_col_seconds(&w, &aw).expect("nonzero basis measures");
        assert!(s > 0.0 && s.is_finite());
        assert!(measure_projection_col_seconds(&Mat::zeros(0, 0), &Mat::zeros(0, 0)).is_none());
    }

    #[test]
    fn strategy_choice_equality_and_debug() {
        assert_eq!(StrategyChoice::default(), StrategyChoice::HarmonicLargest);
        assert_ne!(StrategyChoice::Auto, StrategyChoice::TwoSided);
        let a: Arc<dyn RecycleStrategy> = Arc::new(AdaptiveK);
        let c1 = StrategyChoice::Custom(a.clone());
        let c2 = StrategyChoice::Custom(a);
        let c3 = StrategyChoice::Custom(Arc::new(AdaptiveK));
        assert_eq!(c1, c2);
        assert_ne!(c1, c3);
        assert_ne!(c1, StrategyChoice::Auto);
        assert_eq!(format!("{:?}", StrategyChoice::Auto), "StrategyChoice(adaptive-k)");
    }

    #[test]
    fn decision_savings_matches_choice_savings() {
        let spec = [50.0, 10.0, 2.0, 1.0];
        let c = evaluate_k(&spec, 2, &ctx(128, 4));
        let d = StrategyDecision {
            strategy: "test",
            k_offered: 4,
            k_chosen: c.k,
            predicted_plain_iters: c.plain_iters,
            predicted_deflated_iters: c.deflated_iters,
            predicted_overhead: c.overhead,
        };
        assert!((d.predicted_savings() - c.predicted_savings()).abs() < 1e-12);
    }
}
