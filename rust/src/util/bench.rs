//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, repeated timed runs, and a mean/σ/percentile report.
//! All `benches/*.rs` binaries are `harness = false` and drive this module;
//! `cargo bench` therefore produces one aligned report per paper table or
//! figure.

use crate::util::stats::Summary;
use crate::util::table::{fix, Table};
use std::time::Instant;

/// Configuration for one benchmark group.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub iters: usize,
    /// Hard cap on total recorded time (seconds); stops early when exceeded.
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 2, iters: 10, max_seconds: 30.0 }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Optional throughput denominator (e.g. FLOPs or items per iteration);
    /// if set, the report includes an ops/s column.
    pub work_per_iter: Option<f64>,
}

/// A group of related benchmark cases that renders a single report.
pub struct BenchGroup {
    title: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchGroup {
    pub fn new(title: &str) -> Self {
        // Smoke-mode lets `cargo bench` finish quickly in CI:
        // KRR_BENCH_FAST=1 shrinks the iteration counts.
        let mut cfg = BenchConfig::default();
        if std::env::var("KRR_BENCH_FAST").is_ok() {
            cfg = BenchConfig { warmup: 1, iters: 3, max_seconds: 5.0 };
        }
        BenchGroup { title: title.to_string(), cfg, results: Vec::new() }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        if std::env::var("KRR_BENCH_FAST").is_err() {
            self.cfg = cfg;
        }
        self
    }

    /// Time `f` repeatedly; `f` is the full measured unit (per-iteration).
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        self.bench_with_work(name, None, &mut f)
    }

    /// Like `bench`, with a throughput denominator per iteration.
    pub fn bench_with_work(&mut self, name: &str, work: Option<f64>, f: &mut dyn FnMut()) {
        for _ in 0..self.cfg.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.cfg.iters);
        let budget_start = Instant::now();
        for _ in 0..self.cfg.iters {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_secs_f64());
            if budget_start.elapsed().as_secs_f64() > self.cfg.max_seconds {
                break;
            }
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
            work_per_iter: work,
        });
    }

    /// Render the report table and write CSV under results/bench_<slug>.csv.
    pub fn report(&self) {
        let has_tp = self.results.iter().any(|r| r.work_per_iter.is_some());
        let mut header = vec!["case", "n", "mean [ms]", "std [ms]", "p50 [ms]", "p99 [ms]"];
        if has_tp {
            header.push("Mops/s");
        }
        let mut t = Table::new(&self.title, &header).align(0, crate::util::table::Align::Left);
        for r in &self.results {
            let s = &r.summary;
            let mut row = vec![
                r.name.clone(),
                format!("{}", s.n),
                fix(s.mean * 1e3, 3),
                fix(s.std * 1e3, 3),
                fix(s.p50 * 1e3, 3),
                fix(s.p99 * 1e3, 3),
            ];
            if has_tp {
                row.push(match r.work_per_iter {
                    Some(w) => fix(w / s.mean / 1e6, 1),
                    None => "-".to_string(),
                });
            }
            t.row(row);
        }
        println!("{}", t.render());
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        if let Ok(p) = t.save_csv(&format!("bench_{slug}")) {
            println!("(csv: {})\n", p.display());
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_iters() {
        let mut g = BenchGroup::new("test group")
            .with_config(BenchConfig { warmup: 1, iters: 5, max_seconds: 10.0 });
        let mut x = 0u64;
        g.bench("noop-ish", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        // KRR_BENCH_FAST may shrink iters to 3.
        assert!(g.results()[0].summary.n >= 3);
        assert!(g.results()[0].summary.mean >= 0.0);
    }

    #[test]
    fn throughput_column() {
        let mut g = BenchGroup::new("tp")
            .with_config(BenchConfig { warmup: 0, iters: 3, max_seconds: 10.0 });
        g.bench_with_work("work", Some(1e6), &mut || {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        let r = &g.results()[0];
        assert_eq!(r.work_per_iter, Some(1e6));
        assert!(r.summary.mean > 0.0);
    }

    #[test]
    fn budget_stops_early() {
        let mut g = BenchGroup::new("budget")
            .with_config(BenchConfig { warmup: 0, iters: 1000, max_seconds: 0.05 });
        g.bench("sleepy", || std::thread::sleep(std::time::Duration::from_millis(10)));
        assert!(g.results()[0].summary.n < 1000);
    }
}
