//! A tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! defaults, typed accessors and auto-generated `--help` text. Used by the
//! `krr` binary and every example.

use std::collections::BTreeMap;
use std::fmt;

/// Parse failure (unknown option, missing value, bad type).
#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

#[derive(Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative command-line spec.
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positional: Vec<(String, String)>, // (name, help)
}

/// Parsed argument values.
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positional: Vec::new(),
        }
    }

    /// Register `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Register `--name <value>` that is required (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Register a boolean `--name` flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Register a positional argument (for help text only; all extra
    /// non-option tokens are collected in order).
    pub fn pos(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.to_string(), help.to_string()));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positional.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positional {
                s.push_str(&format!("  <{p:<14}> {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let lhs = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            let dflt = match &o.default {
                Some(d) => format!(" [default: {d}]"),
                None if o.is_flag => String::new(),
                None => " [required]".to_string(),
            };
            s.push_str(&format!("  {lhs:<22} {}{dflt}\n", o.help));
        }
        s.push_str("  --help                 print this help\n");
        s
    }

    /// Parse a token stream (exclusive of argv[0]).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.help_text()));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(CliError(format!("flag --{name} takes no value")));
                    }
                    flags.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError(format!("--{name} needs a value")))?,
                    };
                    values.insert(name, v);
                }
            } else {
                positional.push(tok.clone());
            }
        }
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !values.contains_key(&o.name) {
                return Err(CliError(format!("missing required option --{}", o.name)));
            }
        }
        Ok(Args { values, flags, positional })
    }

    /// Parse the real process arguments; print help and exit on `--help`.
    pub fn parse_env(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(if e.0.contains("USAGE:") { 0 } else { 2 });
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not registered"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{}'", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{}'", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a float, got '{}'", self.get(name)))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not registered"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Parse a comma-separated list of values, e.g. `--sizes 128,256,512`.
    pub fn get_list_usize(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name}: bad integer '{s}'"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("n", "100", "size")
            .opt("tol", "1e-5", "tolerance")
            .flag("verbose", "chatty")
            .req("name", "required name")
            .pos("cmd", "subcommand")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&sv(&["--name", "x"])).unwrap();
        assert_eq!(a.get_usize("n"), 100);
        assert_eq!(a.get_f64("tol"), 1e-5);
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn explicit_values_and_flags() {
        let a = cli()
            .parse(&sv(&["run", "--n", "42", "--verbose", "--name=abc", "--tol=1e-8"]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 42);
        assert_eq!(a.get("name"), "abc");
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get_f64("tol"), 1e-8);
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn missing_required_fails() {
        assert!(cli().parse(&sv(&[])).is_err());
    }

    #[test]
    fn unknown_option_fails() {
        assert!(cli().parse(&sv(&["--name", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value_fails() {
        assert!(cli().parse(&sv(&["--name"])).is_err());
    }

    #[test]
    fn help_contains_options() {
        let h = cli().help_text();
        assert!(h.contains("--tol"));
        assert!(h.contains("[default: 1e-5]"));
        assert!(h.contains("[required]"));
    }

    #[test]
    fn list_parsing() {
        let c = Cli::new("t", "t").opt("sizes", "1,2,3", "sizes");
        let a = c.parse(&sv(&["--sizes", "128, 256,512"])).unwrap();
        assert_eq!(a.get_list_usize("sizes"), vec![128, 256, 512]);
    }
}
