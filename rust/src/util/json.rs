//! Minimal JSON codec (parser + writer).
//!
//! Used for the AOT artifact manifest (written by `python/compile/aot.py`)
//! and for experiment result files. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII manifests),
//! and preserves object insertion order.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap gives deterministic output ordering.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` style access; returns Null when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_num(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), &Json::Null);
        let arr = j.get("a").as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"shape":[128,784],"name":"gram_n128","tol":1e-5,"ok":true}"#;
        let j = Json::parse(src).unwrap();
        for s in [j.to_string(), j.to_string_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), j);
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn get_missing_is_null() {
        let j = Json::parse("{}").unwrap();
        assert_eq!(j.get("nope"), &Json::Null);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
