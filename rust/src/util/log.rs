//! Leveled, timestamped logging to stderr.
//!
//! A global atomic level filter and `info!`/`debug!`/`warn!`-style macros.
//! No external crates: the timestamp is seconds since process start, which
//! is what you want when reading solver traces anyway.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Severity levels, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start reference for log timestamps.
pub fn t0() -> Instant {
    use std::sync::OnceLock;
    static T0: OnceLock<Instant> = OnceLock::new();
    *T0.get_or_init(Instant::now)
}

/// Set the global log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Set level from a string ("error".."trace"); unknown strings keep Info.
pub fn set_level_str(s: &str) {
    let l = match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    };
    set_level(l);
}

/// Whether a message at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a log record (used by the macros).
pub fn emit(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let dt = t0().elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{dt:10.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, module_path!(), format_args!($($a)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn level_str_parsing() {
        set_level_str("trace");
        assert!(enabled(Level::Trace));
        set_level_str("bogus"); // falls back to info
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn emit_does_not_panic() {
        log_info!("hello {}", 42);
        log_debug!("filtered out");
    }
}
