//! Hand-rolled utility substrates.
//!
//! The build environment is fully offline, so every generic dependency a
//! project of this kind would normally pull from crates.io (an async
//! runtime, a CLI parser, a JSON codec, a PRNG, a property-testing
//! framework, a benchmark harness) is implemented here from scratch.
//! Each submodule is deliberately small, dependency-free and unit-tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod pool;
pub mod precision;
pub mod quickprop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
pub mod timer;
