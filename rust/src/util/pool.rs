//! A small fixed-size thread pool with futures-free job handles.
//!
//! Tokio is unavailable offline, and nothing in this system needs an async
//! reactor — the coordinator's concurrency is CPU-bound solver work plus
//! channel-based message passing. This pool provides:
//!
//!   * `ThreadPool::new(n)` — n worker threads pulling from an MPMC queue
//!     (implemented as a `Mutex<VecDeque>` + `Condvar`);
//!   * `spawn` returning a `JobHandle<T>` that can be `join`ed;
//!   * `scope`-free parallel map for static workloads.

use crate::util::sync::lock_unpoisoned;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

/// Handle to a spawned job's result.
pub struct JobHandle<T> {
    slot: Arc<(Mutex<Option<std::thread::Result<T>>>, Condvar)>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes; re-panics if the job panicked.
    pub fn join(self) -> T {
        let (lock, cv) = &*self.slot;
        let mut guard = lock_unpoisoned(lock);
        while guard.is_none() {
            guard = cv.wait(guard).unwrap_or_else(|e| e.into_inner());
        }
        match guard.take().unwrap() {
            Ok(v) => v,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (n >= 1), named
    /// `krr-worker-{i}`.
    pub fn new(n: usize) -> Self {
        Self::with_name(n, "krr-worker")
    }

    /// [`ThreadPool::new`] with a caller-chosen thread-name prefix
    /// (threads are named `{prefix}-{i}`) — with several pools in one
    /// process (scheduler workers vs the matvec compute pool), thread
    /// names are how profilers and stack dumps tell them apart.
    pub fn with_name(n: usize, prefix: &str) -> Self {
        assert!(n >= 1, "ThreadPool needs at least one worker");
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let q = queue.clone();
                std::thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// The machine-sized worker count used by [`ThreadPool::default_size`]
    /// (logical CPUs, capped at 16), exposed so callers building a named
    /// pool can reuse the sizing rule.
    pub fn auto_workers() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .min(16)
    }

    /// Pool sized to the machine (logical CPUs, capped at 16).
    pub fn default_size() -> Self {
        Self::new(Self::auto_workers())
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; returns a joinable handle to its result.
    pub fn spawn<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let slot = Arc::new((Mutex::new(None), Condvar::new()));
        let slot2 = slot.clone();
        let job: Job = Box::new(move || {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let (lock, cv) = &*slot2;
            *lock_unpoisoned(lock) = Some(out);
            cv.notify_all();
        });
        {
            let mut q = lock_unpoisoned(&self.queue.jobs);
            q.push_back(job);
        }
        self.queue.cv.notify_one();
        JobHandle { slot }
    }

    /// Parallel map over an indexed range: applies `f(i)` for i in 0..n and
    /// returns results in order. `f` is cloned per job.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + Clone + 'static,
    {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let g = f.clone();
                self.spawn(move || g(i))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut jobs = lock_unpoisoned(&q.jobs);
            loop {
                if let Some(j) = jobs.pop_front() {
                    break Some(j);
                }
                if q.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                jobs = q.cv.wait(jobs).unwrap_or_else(|e| e.into_inner());
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::Release);
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn spawn_and_join() {
        let pool = ThreadPool::new(2);
        let h = pool.spawn(|| 6 * 7);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_workers_participate_under_load() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..64)
            .map(|_| {
                let c = counter.clone();
                pool.spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn panicking_job_repanic_on_join() {
        let pool = ThreadPool::new(1);
        let h = pool.spawn(|| panic!("boom"));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.join()));
        assert!(res.is_err());
        // Pool still usable after a panic.
        assert_eq!(pool.spawn(|| 1).join(), 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let h = pool.spawn(|| 5);
        assert_eq!(h.join(), 5);
        drop(pool); // must not hang
    }
}
