//! Sanctioned float-width conversions.
//!
//! The lint's `lossy-cast` rule bans bare `as f32` / `as f64` in
//! `solvers/`, `linalg/`, `benches/` and `examples/`: an `as` cast is
//! silent about whether it loses information, and a numerics codebase
//! accumulates them until nobody can say which ones matter. Every
//! float-width change in swept code routes through this module instead,
//! so each conversion states its contract at the call site:
//!
//! - [`to_f64`] — lossless-by-construction widening from integer
//!   counters and sizes (debug-asserted under 2⁵³, where every integer
//!   is exactly representable).
//! - [`promote`] — exact f32 → f64 widening (every f32 is an f64).
//! - [`demote`] / [`to_f32`] — the one *deliberately* lossy direction
//!   (rounds to nearest f32), for mixed-precision boundaries like the
//!   XLA/accelerator interface. Grep for these to find every place the
//!   codebase gives up f64 precision.

/// Integer-like values that widen into `f64` without losing magnitude
/// information in practice. See [`to_f64`].
pub trait ToF64 {
    fn to_f64(self) -> f64;
}

/// Values that narrow into `f32`. See [`to_f32`].
pub trait ToF32 {
    fn to_f32(self) -> f32;
}

// 2^53: the largest width below which every integer has an exact f64
// representation. Counters (iterations, matvecs, bytes, lengths) sit
// far under it; the debug assert documents the contract and catches a
// future misuse with a genuinely huge value.
const EXACT_F64: u64 = 1 << 53;

macro_rules! impl_to_f64_int {
    ($($t:ty),*) => {$(
        impl ToF64 for $t {
            #[inline]
            fn to_f64(self) -> f64 {
                debug_assert!(
                    (self as u128) < (EXACT_F64 as u128),
                    "integer {} exceeds 2^53; f64 can no longer hold it exactly",
                    self
                );
                self as f64 // the sanctioned cast: util/ sits outside the lossy-cast sweep
            }
        }
    )*};
}

impl_to_f64_int!(u8, u16, u32, u64, usize);

macro_rules! impl_to_f64_sint {
    ($($t:ty),*) => {$(
        impl ToF64 for $t {
            #[inline]
            fn to_f64(self) -> f64 {
                debug_assert!(
                    self.unsigned_abs() as u128 < EXACT_F64 as u128,
                    "integer {} exceeds 2^53 in magnitude; f64 can no longer hold it exactly",
                    self
                );
                self as f64 // the sanctioned cast: util/ sits outside the lossy-cast sweep
            }
        }
    )*};
}

impl_to_f64_sint!(i8, i16, i32, i64, isize);

impl ToF64 for f32 {
    #[inline]
    fn to_f64(self) -> f64 {
        f64::from(self)
    }
}

impl ToF64 for f64 {
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
}

macro_rules! impl_to_f32_int {
    ($($t:ty),*) => {$(
        impl ToF32 for $t {
            #[inline]
            fn to_f32(self) -> f32 {
                self as f32 // the sanctioned cast: util/ sits outside the lossy-cast sweep
            }
        }
    )*};
}

impl_to_f32_int!(u8, u16, u32, u64, usize, i8, i16, i32, i64, isize);

impl ToF32 for f64 {
    #[inline]
    fn to_f32(self) -> f32 {
        self as f32 // the sanctioned cast: util/ sits outside the lossy-cast sweep
    }
}

/// Widen an integer counter/size (or an f32) to `f64`.
/// Debug-asserts the value sits under 2⁵³ so the widening is exact.
#[inline]
pub fn to_f64<T: ToF64>(x: T) -> f64 {
    x.to_f64()
}

/// Narrow to `f32`, rounding to nearest. Deliberately lossy — use at
/// mixed-precision boundaries only.
#[inline]
pub fn to_f32<T: ToF32>(x: T) -> f32 {
    x.to_f32()
}

/// Exact f32 → f64 widening.
#[inline]
pub fn promote(x: f32) -> f64 {
    f64::from(x)
}

/// f64 → f32 narrowing, rounding to nearest. The explicit name marks
/// the precision loss that a bare `as f32` would hide.
#[inline]
pub fn demote(x: f64) -> f32 {
    x as f32 // the sanctioned cast: util/ sits outside the lossy-cast sweep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integer_widening_is_exact_for_counters() {
        assert_eq!(to_f64(0usize), 0.0);
        assert_eq!(to_f64(1usize << 40), (1u64 << 40) as f64);
        assert_eq!(to_f64(-7i64), -7.0);
        assert_eq!(to_f64(u32::MAX), 4294967295.0);
    }

    #[test]
    fn promote_demote_round_trip_on_f32_values() {
        for &v in &[0.0f32, 1.5, -3.25, f32::MIN_POSITIVE, 1e30] {
            assert_eq!(demote(promote(v)), v);
        }
    }

    #[test]
    fn demote_rounds_to_nearest() {
        // 1 + 2⁻²⁶ is below half an f32 ULP at 1.0 — rounds back to 1.
        assert_eq!(demote(1.0 + 2f64.powi(-26)), 1.0f32);
        assert_eq!(to_f32(3usize), 3.0f32);
    }

    #[test]
    fn f32_and_f64_widen_losslessly() {
        assert_eq!(to_f64(0.5f32), 0.5);
        assert_eq!(to_f64(2.25f64), 2.25);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "exceeds 2^53")]
    fn widening_a_too_large_counter_panics_in_debug() {
        let _ = to_f64((1u64 << 53) + 1);
    }
}
