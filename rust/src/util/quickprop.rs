//! `quickprop` — a miniature property-based testing framework.
//!
//! proptest/quickcheck are unavailable offline, so this module provides the
//! subset we need: seeded generators built on [`crate::util::rng::Rng`], a
//! `forall` runner that reports the failing case and its seed, and simple
//! shrinking for numeric vectors (halving toward zero / shortening).
//!
//! Usage:
//! ```no_run
//! use krr::util::quickprop::{forall, Gen};
//! forall("sum is commutative", 100, |g| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     ((a + b) - (b + a)).abs() < 1e-12
//! });
//! ```

use crate::util::rng::Rng;

/// Value generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Log of generated values, printed on failure.
    pub trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    /// Build a generator around an existing RNG (used by non-test code that
    /// wants the structured generators, e.g. random SPD matrices).
    pub fn from_rng(rng: Rng) -> Self {
        Gen { rng, trace: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let v = lo + self.rng.below((hi - lo) as u64) as usize;
        self.trace.push(format!("usize {v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform_in(lo, hi);
        self.trace.push(format!("f64 {v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool {v}"));
        v
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let v: Vec<f64> = (0..n).map(|_| self.rng.normal()).collect();
        self.trace.push(format!("normal_vec len={n}"));
        v
    }

    /// Vector of normals as f32.
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        let v: Vec<f32> = (0..n).map(|_| self.rng.normal() as f32).collect();
        self.trace.push(format!("normal_vec_f32 len={n}"));
        v
    }

    /// A random SPD matrix (row-major, n*n) as `M = QᵀDQ + εI` built from
    /// random Householder reflections and positive diagonal — the standard
    /// way to get a controllable spectrum for solver tests.
    pub fn spd_matrix(&mut self, n: usize, cond: f64) -> Vec<f64> {
        // Eigenvalues log-spaced in [1, cond].
        let mut a = vec![0.0; n * n];
        let eigs: Vec<f64> = (0..n)
            .map(|i| {
                if n == 1 {
                    1.0
                } else {
                    (cond.ln() * i as f64 / (n - 1) as f64).exp()
                }
            })
            .collect();
        for (i, &e) in eigs.iter().enumerate() {
            a[i * n + i] = e;
        }
        // Apply a few random Householder similarity transforms: A <- H A H.
        for _ in 0..3 {
            let v = {
                let mut v: Vec<f64> = (0..n).map(|_| self.rng.normal()).collect();
                let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
                if norm < 1e-12 {
                    continue;
                }
                for x in &mut v {
                    *x /= norm;
                }
                v
            };
            // H = I - 2 v vᵀ; compute A <- H A H in O(n²).
            // w = A v ; A <- A - 2 v wᵀ - 2 (A v) vᵀ ... do it via two rank-1 updates:
            // B = A - 2 v (vᵀ A); C = B - 2 (B v) vᵀ.
            let mut vta = vec![0.0; n];
            for i in 0..n {
                for j in 0..n {
                    vta[j] += v[i] * a[i * n + j];
                }
            }
            for i in 0..n {
                for j in 0..n {
                    a[i * n + j] -= 2.0 * v[i] * vta[j];
                }
            }
            let mut bv = vec![0.0; n];
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += a[i * n + j] * v[j];
                }
                bv[i] = s;
            }
            for i in 0..n {
                for j in 0..n {
                    a[i * n + j] -= 2.0 * bv[i] * v[j];
                }
            }
        }
        // Symmetrize against accumulated round-off.
        for i in 0..n {
            for j in (i + 1)..n {
                let m = 0.5 * (a[i * n + j] + a[j * n + i]);
                a[i * n + j] = m;
                a[j * n + i] = m;
            }
        }
        self.trace.push(format!("spd_matrix n={n} cond={cond}"));
        a
    }
}

/// Run `prop` for `iters` seeded cases; panics with the seed and the
/// generated-value trace of the first failing case.
pub fn forall(name: &str, iters: u64, mut prop: impl FnMut(&mut Gen) -> bool) {
    // Base seed is fixed for reproducibility; override with KRR_QP_SEED.
    let base = std::env::var("KRR_QP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000u64);
    for case in 0..iters {
        let seed = base.wrapping_add(case);
        let mut g = Gen::new(seed);
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        match ok {
            Ok(true) => {}
            Ok(false) => panic!(
                "property '{name}' FALSIFIED at case {case} (seed {seed:#x})\n  trace: {:?}",
                g.trace
            ),
            Err(p) => panic!(
                "property '{name}' PANICKED at case {case} (seed {seed:#x})\n  trace: {:?}\n  panic: {:?}",
                g.trace,
                p.downcast_ref::<&str>()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("tautology", 50, |_g| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "FALSIFIED")]
    fn failing_property_reports() {
        forall("always false", 5, |g| {
            let _ = g.usize_in(0, 10);
            false
        });
    }

    #[test]
    #[should_panic(expected = "PANICKED")]
    fn panicking_property_reports() {
        forall("panics", 3, |_g| panic!("inner"));
    }

    #[test]
    fn spd_matrix_is_symmetric_with_positive_diag() {
        forall("spd gen", 10, |g| {
            let n = g.usize_in(2, 12);
            let a = g.spd_matrix(n, 100.0);
            let mut ok = true;
            for i in 0..n {
                ok &= a[i * n + i] > 0.0;
                for j in 0..n {
                    ok &= (a[i * n + j] - a[j * n + i]).abs() < 1e-9;
                }
            }
            ok
        });
    }

    #[test]
    fn spd_matrix_quadratic_form_positive() {
        forall("spd positive definite", 10, |g| {
            let n = g.usize_in(2, 10);
            let a = g.spd_matrix(n, 50.0);
            let v = g.normal_vec(n);
            let mut q = 0.0;
            for i in 0..n {
                for j in 0..n {
                    q += v[i] * a[i * n + j] * v[j];
                }
            }
            let vv = v.iter().map(|x| x * x).sum::<f64>();
            vv < 1e-12 || q > 0.0
        });
    }
}
