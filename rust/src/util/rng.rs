//! Deterministic pseudo-random number generation.
//!
//! Implements xoshiro256++ (Blackman & Vigna), a small, fast, high-quality
//! non-cryptographic PRNG, plus the distribution helpers the rest of the
//! library needs (uniform, standard normal via Box–Muller, shuffling,
//! subsampling). Everything is seedable so experiments are reproducible
//! bit-for-bit.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

/// SplitMix64 step, used to expand a single `u64` seed into the xoshiro
/// state (the construction recommended by the xoshiro authors).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output (xoshiro256++ core step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits -> [0,1) double with full mantissa resolution.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller (caches the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let mut u = self.uniform();
        while u <= f64::MIN_POSITIVE {
            u = self.uniform();
        }
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k positions need finalizing.
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a statistically independent child generator (for per-thread use).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xDEAD_BEEF_CAFE_F00D)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_indices_full() {
        let mut r = Rng::new(9);
        let mut idx = r.sample_indices(10, 10);
        idx.sort_unstable();
        assert_eq!(idx, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn fork_diverges_from_parent() {
        let mut a = Rng::new(21);
        let mut child = a.fork();
        let same = (0..64).filter(|_| a.next_u64() == child.next_u64()).count();
        assert_eq!(same, 0);
    }
}
