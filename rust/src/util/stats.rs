//! Streaming summary statistics (Welford) and percentile summaries.

/// Online mean/variance accumulator (Welford's algorithm) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample set (linear interpolation between order stats).
/// `q` in [0, 1]. Sorts a copy; intended for end-of-run summaries.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q out of range");
    let mut v: Vec<f64> = xs.to_vec();
    // `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN timing sample
    // (e.g. 0/0 from a zero-iteration run) must not panic the
    // end-of-run summary; NaN sorts above +inf and lands in the tail.
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Fixed-size summary of a timing distribution.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: w.min(),
            p50: percentile(xs, 0.50),
            p90: percentile(xs, 0.90),
            p99: percentile(xs, 0.99),
            max: w.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 3.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // Regression for the PR 6 class of failures: a NaN sample used
        // to panic the `partial_cmp(..).unwrap()` sort. With `total_cmp`
        // NaN sorts above +inf, so low/mid percentiles stay finite and
        // meaningful while the NaN is confined to the extreme tail.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!((percentile(&xs, 1.0 / 3.0) - 2.0).abs() < 1e-12);
        assert!(percentile(&xs, 1.0).is_nan(), "NaN lands at the top");
    }

    #[test]
    fn summary_sane() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1.0);
        assert!(s.p90 > s.p50 && s.p99 > s.p90);
    }

    #[test]
    fn single_sample_variance_zero() {
        let mut w = Welford::new();
        w.push(5.0);
        assert_eq!(w.var(), 0.0);
        assert_eq!(w.std(), 0.0);
    }
}
