//! Sync-primitive shim: `std::sync`/`std::thread` by default, [`loom`]'s
//! model-checked replacements under `RUSTFLAGS="--cfg loom"`.
//!
//! The coordinator's concurrency protocols (the scheduled-flag
//! one-entry-anywhere handshake, the `Slot` one-shot state machine, the
//! busy→stamp→completed snapshot ordering, the all-of group-cancel set,
//! the byte accountant's settle-after-unlock `try_lock` dance) are
//! load-bearing for *numerical* correctness: a race that mixes one
//! sequence's `(W, AW)` basis into another's produces a silently wrong
//! deflation space, not a crash. `rust/tests/loom_models.rs` model-checks
//! small-N versions of those protocols exhaustively; for the checked code
//! to be the shipped code, every shimmed module must reach its
//! primitives through this module instead of `std::sync`/`std::thread`
//! (mechanically enforced by the `std-sync-in-shimmed` rule of
//! `cargo run -p lint`).
//!
//! # Shimmed modules
//!
//! `coordinator::scheduler`, `coordinator::service` (including the
//! `ServiceMetrics` counters) and `solvers::control`. Everything else —
//! the thread pool, the solver kernels, the experiments — keeps using
//! `std` directly: their concurrency is either absent or fork/join
//! structured, and dragging them under the shim would only grow loom's
//! state space without adding a checked protocol.
//!
//! # What switches and what deliberately does not
//!
//! * [`Mutex`], [`Condvar`], [`atomic`], [`thread`]: `std` by default,
//!   `loom` under `cfg(loom)`. These are the primitives whose
//!   interleavings loom explores.
//! * [`Arc`], [`Weak`], [`OnceLock`]: **always `std`**. Loom's `Arc`
//!   does not support `Weak` (the service's sequence registry and byte
//!   accountant need downgrades), and loom has no `OnceLock`.
//!   Reference-counted lifetime is not one of the modeled protocols;
//!   `std`'s refcounting is sound inside a loom model — loom simply does
//!   not explore its orderings.
//!
//! [`loom`] is **not** vendored into the offline tree (mirroring the
//! `pjrt` feature's unvendored `xla` dependency): the default build is
//! dependency-free and bitwise-unchanged. CI materializes it with
//! `cargo add loom@0.7 --dev --target 'cfg(loom)' -p krr` before running
//! the model suite; do the same locally. See DESIGN.md §"Correctness
//! tooling".
//!
//! [`loom`]: https://docs.rs/loom

// The refcounting primitives stay `std` in both worlds — see module docs.
pub use std::sync::{Arc, OnceLock, Weak};

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, TryLockError};

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard, TryLockError};

/// Atomics for the shimmed modules. Note that loom's atomics have
/// non-`const` constructors: shimmed types must build their atomics at
/// runtime (struct fields, not `static`s).
#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Atomics for the shimmed modules (loom build).
#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Thread spawning for the shimmed modules.
#[cfg(not(loom))]
pub mod thread {
    pub use std::thread::{spawn, yield_now, Builder, JoinHandle};
}

/// Thread spawning for the shimmed modules (loom build). Loom threads
/// exist only inside `loom::model` closures; code paths that spawn
/// through this module must not run outside a model in a loom build
/// (the model suite never constructs a full `Scheduler`).
#[cfg(loom)]
pub mod thread {
    pub use loom::thread::{spawn, yield_now, JoinHandle};

    /// Minimal `std::thread::Builder`-compatible shim: loom has no named
    /// threads, so the name is accepted and dropped.
    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        pub fn new() -> Builder {
            Builder { name: None }
        }

        pub fn name(mut self, name: String) -> Builder {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T,
            F: Send + 'static,
            T: Send + 'static,
        {
            let _ = self.name;
            Ok(spawn(f))
        }
    }

    impl Default for Builder {
        fn default() -> Builder {
            Builder::new()
        }
    }
}

/// Recover a mutex guard even when a previous holder panicked: the
/// coordinator must keep dispatching after a contained worker failure
/// (the failed request completes as `StopReason::Failed`; recycle state
/// a panicked solve may have half-updated is still structurally valid —
/// basis absorption is transactional, it happens only after a solve
/// returns). `#[track_caller]` makes the recovery log name the real
/// call site instead of this helper.
#[track_caller]
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| {
        crate::log_warn!("recovered poisoned mutex at {}", std::panic::Location::caller());
        e.into_inner()
    })
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn lock_unpoisoned_returns_guard() {
        let m = Mutex::new(7);
        assert_eq!(*lock_unpoisoned(&m), 7);
    }

    #[test]
    fn lock_unpoisoned_recovers_after_holder_panic() {
        let m = Arc::new(Mutex::new(0));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap(); // lint:allow(bare-lock-unwrap) — poisoning on purpose
            panic!("poison the mutex");
        })
        .join();
        // A bare .lock().unwrap() would panic here; the helper recovers.
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 1);
    }

    #[test]
    fn shim_thread_builder_matches_std_surface() {
        let h = thread::Builder::new()
            .name("krr-shim-test".to_string())
            .spawn(|| 41 + 1)
            .expect("spawn");
        assert_eq!(h.join().unwrap(), 42);
    }
}
