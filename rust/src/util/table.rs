//! Aligned text tables and CSV output for experiment reports.
//!
//! Every experiment in `experiments/` renders its results with this module
//! so that `krr table1` prints something visually comparable to the paper's
//! Table 1, and simultaneously writes machine-readable CSV to `results/`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table: header row + data rows of strings.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Right; header.len()],
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "## {}", self.title);
        }
        let fmt_row = |cells: &[String], w: &[usize], aligns: &[Align]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = w[i] - c.chars().count();
                match aligns[i] {
                    Align::Left => {
                        let _ = write!(line, " {}{} |", c, " ".repeat(pad));
                    }
                    Align::Right => {
                        let _ = write!(line, " {}{} |", " ".repeat(pad), c);
                    }
                }
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &w, &self.aligns));
        let mut sep = String::from("|");
        for wi in &w {
            let _ = write!(sep, "{}|", "-".repeat(wi + 2));
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r, &w, &self.aligns));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV under `results/<name>.csv` (creating the directory).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path)
    }
}

/// Format a float in scientific notation like the paper ("8.573e-03").
pub fn sci(x: f64) -> String {
    format!("{x:.3e}")
}

/// Format a float with fixed decimals.
pub fn fix(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["it", "value"]).align(0, Align::Left);
        t.row(vec!["1".into(), "-4926.523".into()]);
        t.row(vec!["10".into(), "-1.2".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("## demo"));
        // all table lines equal width
        let widths: Vec<usize> = lines[1..].iter().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
        assert!(s.contains("| 1  |"));
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(8.573e-3), "8.573e-3");
        assert_eq!(fix(-4926.5231, 3), "-4926.523");
    }
}
