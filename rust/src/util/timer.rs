//! Wall-clock timing helpers and a cumulative stopwatch.

use std::time::{Duration, Instant};

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// A stopwatch that can be started/stopped repeatedly and accumulates.
/// Used for the paper's "cumulative runtime" columns (Table 1).
#[derive(Debug, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { total: Duration::ZERO, started: None }
    }

    pub fn start(&mut self) {
        assert!(self.started.is_none(), "Stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        let s = self.started.take().expect("Stopwatch not running");
        self.total += s.elapsed();
    }

    /// Run a closure with the watch running.
    pub fn measure<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    /// Cumulative elapsed seconds (excluding a currently-running segment).
    pub fn seconds(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.total = Duration::ZERO;
        self.started = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (v, t) = time_it(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(t >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut w = Stopwatch::new();
        w.measure(|| std::thread::sleep(Duration::from_millis(5)));
        let t1 = w.seconds();
        assert!(t1 >= 0.004, "t1={t1}");
        w.measure(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(w.seconds() > t1);
        w.reset();
        assert_eq!(w.seconds(), 0.0);
    }

    #[test]
    #[should_panic(expected = "not running")]
    fn stop_without_start_panics() {
        Stopwatch::new().stop();
    }
}
