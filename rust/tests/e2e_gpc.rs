//! End-to-end integration: the full GPC pipeline on the native backend.
//!
//! Exercises data generation → Gram assembly → Laplace/Newton → recycled
//! def-CG → prediction, and cross-checks the three solver backends, the
//! coordinator service, and the hyperparameter loop at a size that keeps
//! CI fast but non-trivial.

use krr::coordinator::SolveService;
use krr::data::digits::{generate, DigitsConfig};
use krr::gp::kernel::RbfKernel;
use krr::gp::laplace::{DenseKernel, LaplaceConfig, LaplaceGpc, SolverBackend};
use krr::solvers::SolveSpec;
use krr::solvers::recycle::RecycleConfig;
use krr::solvers::SpdOperator;
use std::sync::Arc;

const N: usize = 128;

fn fit(backend: SolverBackend) -> krr::gp::laplace::LaplaceFit {
    let ds = generate(&DigitsConfig { n: N, seed: 21, ..Default::default() });
    let k = DenseKernel::new(RbfKernel::new(1.0, 10.0).gram(&ds.x));
    let cfg = LaplaceConfig {
        solver: backend,
        solve_tol: 1e-6,
        newton_tol: 1e-2,
        max_newton: 15,
        ..Default::default()
    };
    LaplaceGpc::new(&k, &ds.y, cfg).fit()
}

#[test]
fn three_backends_reach_the_same_mode() {
    let chol = fit(SolverBackend::Cholesky);
    let cg = fit(SolverBackend::Cg);
    let defcg = fit(SolverBackend::DefCg(RecycleConfig {
        k: 8,
        l: 12,
        ..Default::default()
    }));
    assert!(chol.converged && cg.converged && defcg.converged);
    let c = chol.final_log_lik();
    for (name, f) in [("cg", &cg), ("defcg", &defcg)] {
        let d = (f.final_log_lik() - c).abs() / c.abs();
        assert!(d < 1e-3, "{name} diverged from cholesky: {d}");
    }
    // Recycling must save iterations overall (systems 2+).
    let tail = |f: &krr::gp::laplace::LaplaceFit| {
        f.steps.iter().skip(1).map(|s| s.solver_iterations).sum::<usize>()
    };
    assert!(tail(&defcg) < tail(&cg));
}

#[test]
fn classification_quality_on_heldout_data() {
    let all = generate(&DigitsConfig { n: N + 40, seed: 22, ..Default::default() });
    let mut rng = krr::util::rng::Rng::new(5);
    let (train, test) = all.split(N as f64 / all.n() as f64, &mut rng);
    let kernel = RbfKernel::new(1.0, 10.0);
    let k = DenseKernel::new(kernel.gram(&train.x));
    let mut gpc = LaplaceGpc::new(
        &k,
        &train.y,
        LaplaceConfig {
            solver: SolverBackend::DefCg(RecycleConfig::default()),
            solve_tol: 1e-6,
            newton_tol: 1e-2,
            max_newton: 15,
            ..Default::default()
        },
    );
    let fit = gpc.fit();
    let cross = kernel.cross_gram(&train.x, &test.x);
    let f_test = gpc.predict_latent(&cross, &fit);
    let acc = test
        .y
        .iter()
        .zip(&f_test)
        .filter(|(&y, &f)| y * f > 0.0)
        .count() as f64
        / test.n() as f64;
    assert!(acc > 0.9, "held-out accuracy {acc}");
}

#[test]
fn coordinator_runs_the_newton_sequence() {
    // Drive the Newton systems through the coordinator service, as the
    // solver_service example does, and verify recycling kicks in.
    struct NewtonOp {
        k: krr::linalg::Mat,
        s: Vec<f64>,
    }
    impl SpdOperator for NewtonOp {
        fn n(&self) -> usize {
            self.s.len()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            let n = self.s.len();
            let sx: Vec<f64> = (0..n).map(|i| self.s[i] * x[i]).collect();
            let ksx = self.k.matvec(&sx);
            for i in 0..n {
                y[i] = x[i] + self.s[i] * ksx[i];
            }
        }
    }
    let ds = generate(&DigitsConfig { n: N, seed: 23, ..Default::default() });
    let k = RbfKernel::new(1.0, 10.0).gram(&ds.x);
    let svc = SolveService::new(2);
    let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
    let mut iters = Vec::new();
    for i in 0..4 {
        let s: Vec<f64> = (0..N).map(|j| 0.5 - 0.03 * i as f64 + 1e-3 * (j % 7) as f64).collect();
        let op = Arc::new(NewtonOp { k: k.clone(), s });
        let b: Vec<f64> = ds.y.iter().map(|&v| v * 0.5).collect();
        let r = seq.submit(op, b, None, SolveSpec::defcg().with_tol(1e-6)).wait();
        assert_eq!(r.stop, krr::solvers::StopReason::Converged);
        iters.push(r.iterations);
    }
    assert!(iters[3] < iters[0], "no recycling benefit: {iters:?}");
}

#[test]
fn coordinator_parallel_operator_reproduces_serial_sequence() {
    // The service's ParDenseOp path (dense matvec sharded on the compute
    // pool) must reproduce the serial sequence exactly: shards preserve
    // the per-row dot order, so every CG trajectory is bitwise identical.
    let n = 300;
    let mut rng = krr::util::rng::Rng::new(31);
    let a = krr::linalg::Mat::rand_spd(n, 1e4, &mut rng);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 6) as f64).collect();
    let spec = SolveSpec::defcg().with_tol(1e-8);
    let svc = SolveService::new(2);

    struct Owned(krr::linalg::Mat);
    impl SpdOperator for Owned {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.0.matvec_into(x, y);
        }
    }

    let par_seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
    let ser_seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
    let par_op = svc.par_operator(a.clone());
    let ser_op = Arc::new(Owned(a));
    for _ in 0..3 {
        let rp = par_seq.submit(par_op.clone(), b.clone(), None, spec.clone()).wait();
        let rs = ser_seq.submit(ser_op.clone(), b.clone(), None, spec.clone()).wait();
        assert_eq!(rp.stop, krr::solvers::StopReason::Converged);
        assert_eq!(rp.iterations, rs.iterations);
        for (u, v) in rp.x.iter().zip(&rs.x) {
            assert_eq!(u, v);
        }
    }
    assert!(par_seq.k_active() > 0);
}

#[test]
fn hyperparameter_search_agrees_across_backends() {
    let ds = generate(&DigitsConfig { n: 64, seed: 24, ..Default::default() });
    let cg = krr::gp::hyper::grid_search(&ds, &[1.0], &[3.0, 10.0, 30.0], SolverBackend::Cg, 8);
    let def = krr::gp::hyper::grid_search(
        &ds,
        &[1.0],
        &[3.0, 10.0, 30.0],
        SolverBackend::DefCg(RecycleConfig::default()),
        8,
    );
    assert_eq!(cg.best.lengthscale, def.best.lengthscale);
    let tot = |r: &krr::gp::hyper::HyperSearchResult| {
        r.evaluated.iter().map(|p| p.solver_iterations).sum::<usize>()
    };
    assert!(tot(&def) <= tot(&cg));
}
