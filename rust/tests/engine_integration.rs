//! Integration: the engine path vs the rust-native reference path.
//!
//! The engine under test is backend-pluggable: the default build runs
//! every case against the pure-Rust [`krr::runtime::NativeEngine`]
//! (embedded manifest, f32 artifact semantics); with the `pjrt` feature
//! *and* `make artifacts` done, the same cases run against the compiled
//! PJRT artifacts instead. Either way the cross-layer contract is the
//! same: the L1/L2 computations served through the engine call surface
//! must agree with the independent f64 rust implementations to f32
//! precision.

use krr::data::digits::{generate, DigitsConfig};
use krr::gp::kernel::RbfKernel;
use krr::gp::laplace::{DenseKernel, KernelOp, LaplaceConfig, LaplaceGpc, SolverBackend};
use krr::linalg::mat::Mat;
use krr::runtime::engine::{Engine, Tensor};
use krr::runtime::ops::{EngineKernel, EngineMatrixFreeKernel, EngineSpdOperator};
use krr::solvers::{self, SolveSpec, SpdOperator, StopReason};
use krr::util::rng::Rng;
use std::sync::Arc;

const N: usize = 64; // must be one of the manifest sizes

/// The engine under test. PJRT-only preconditions live behind the
/// `pjrt` feature; everything below runs identically on both backends.
fn engine() -> Arc<Engine> {
    if cfg!(feature = "pjrt") {
        assert!(
            Engine::available("artifacts"),
            "pjrt feature set but artifacts/ not built (run `make artifacts`)"
        );
        return Arc::new(Engine::load("artifacts").expect("engine load"));
    }
    Arc::new(Engine::native())
}

#[test]
fn engine_backend_matches_build_features() {
    let eng = engine();
    #[cfg(feature = "pjrt")]
    assert_eq!(eng.backend_name(), "pjrt");
    #[cfg(not(feature = "pjrt"))]
    assert_eq!(eng.backend_name(), "native");
    assert!(eng.manifest().sizes.contains(&N));
}

/// Feature tensor for N digit images.
fn features() -> (Tensor, Vec<f64>, Mat) {
    let ds = generate(&DigitsConfig { n: N, seed: 42, ..Default::default() });
    let x32 = Tensor::mat(N, 784, ds.x.to_f32());
    (x32, ds.y.clone(), ds.x)
}

#[test]
fn gram_artifact_matches_native_kernel() {
    let eng = engine();
    let (x32, _y, x) = features();
    let (amp, ls) = (1.3, 9.0);
    let out = eng
        .call(
            &format!("gram_n{N}"),
            &[x32, Tensor::param(amp as f32), Tensor::param(ls as f32)],
        )
        .unwrap();
    let native = RbfKernel::new(amp, ls).gram(&x);
    let got = Mat::from_f32(N, N, &out[0].data);
    let diff = got.max_abs_diff(&native);
    assert!(diff < 1e-4, "gram mismatch: {diff}");
}

#[test]
fn kmatvec_and_amatvec_match_native() {
    let eng = engine();
    let (x32, _y, x) = features();
    let k_native = RbfKernel::new(1.0, 10.0).gram(&x);
    let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();

    let mut rng = Rng::new(1);
    let v: Vec<f64> = (0..N).map(|_| rng.normal()).collect();
    // kmatvec
    let mut got = vec![0.0; N];
    ek.matvec(&v, &mut got);
    let want = k_native.matvec(&v);
    for i in 0..N {
        assert!((got[i] - want[i]).abs() < 1e-3, "kmatvec[{i}]: {} vs {}", got[i], want[i]);
    }
    // amatvec
    let s: Vec<f64> = (0..N).map(|i| 0.1 + 0.2 * ((i % 5) as f64)).collect();
    let op = EngineSpdOperator::new(&ek, &s);
    let got_a = op.matvec_alloc(&v);
    let want_a: Vec<f64> = {
        let sv: Vec<f64> = s.iter().zip(&v).map(|(a, b)| a * b).collect();
        let ksv = k_native.matvec(&sv);
        (0..N).map(|i| v[i] + s[i] * ksv[i]).collect()
    };
    for i in 0..N {
        assert!(
            (got_a[i] - want_a[i]).abs() < 1e-3,
            "amatvec[{i}]: {} vs {}",
            got_a[i],
            want_a[i]
        );
    }
}

#[test]
fn matrix_free_kernel_matches_materialized() {
    let eng = engine();
    let (x32, _y, x) = features();
    let mf = EngineMatrixFreeKernel::new(eng.clone(), &x32, 1.0, 10.0).unwrap();
    let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();
    let _ = x;
    let mut rng = Rng::new(2);
    let v: Vec<f64> = (0..N).map(|_| rng.normal()).collect();
    let mut a = vec![0.0; N];
    let mut b = vec![0.0; N];
    mf.matvec(&v, &mut a);
    ek.matvec(&v, &mut b);
    for i in 0..N {
        assert!((a[i] - b[i]).abs() < 2e-3, "[{i}] {} vs {}", a[i], b[i]);
    }
}

#[test]
fn newton_stats_artifact_matches_native_math() {
    let eng = engine();
    let (x32, y, x) = features();
    let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();
    let k_native = RbfKernel::new(1.0, 10.0).gram(&x);

    let mut rng = Rng::new(3);
    let f: Vec<f64> = (0..N).map(|_| rng.normal() * 0.5).collect();
    let (rhs, s, b_rw, loglik) = ek.newton_stats(&f, &y).unwrap();

    // Native recomputation.
    let lik = krr::gp::likelihood::Logistic;
    let mut grad = vec![0.0; N];
    let mut h = vec![0.0; N];
    lik.grad(&y, &f, &mut grad);
    lik.hess_diag(&f, &mut h);
    let s_w: Vec<f64> = h.iter().map(|v| v.sqrt()).collect();
    let b_w: Vec<f64> = (0..N).map(|i| h[i] * f[i] + grad[i]).collect();
    let kb = k_native.matvec(&b_w);
    let rhs_w: Vec<f64> = (0..N).map(|i| s_w[i] * kb[i]).collect();
    let ll_w = lik.log_lik(&y, &f);

    for i in 0..N {
        assert!((s[i] - s_w[i]).abs() < 1e-5);
        assert!((b_rw[i] - b_w[i]).abs() < 1e-5);
        assert!((rhs[i] - rhs_w[i]).abs() < 1e-3, "rhs[{i}] {} vs {}", rhs[i], rhs_w[i]);
    }
    assert!((loglik - ll_w).abs() / ll_w.abs() < 1e-4);
}

#[test]
fn cg_on_engine_operator_converges_and_matches_native_solution() {
    let eng = engine();
    let (x32, _y, x) = features();
    let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();
    let k_native = RbfKernel::new(1.0, 10.0).gram(&x);

    let s: Vec<f64> = (0..N).map(|i| 0.3 + 0.01 * (i as f64)).collect();
    let b: Vec<f64> = (0..N).map(|i| ((i % 7) as f64) - 3.0).collect();
    let op = EngineSpdOperator::new(&ek, &s);
    let r = solvers::solve(&op, &b, &SolveSpec::cg().with_tol(1e-5));
    assert_eq!(r.stop, StopReason::Converged);

    // Native solve of the same system for reference.
    let mut a = Mat::from_fn(N, N, |i, j| s[i] * k_native[(i, j)] * s[j]);
    a.add_diag(1.0);
    let want = krr::solvers::direct::solve(&a, &b).x;
    for i in 0..N {
        assert!(
            (r.x[i] - want[i]).abs() < 1e-3,
            "x[{i}] {} vs {}",
            r.x[i],
            want[i]
        );
    }
}

#[test]
fn full_laplace_through_engine_matches_native_backend() {
    let eng = engine();
    let (x32, y, x) = features();
    let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();

    let cfg = LaplaceConfig {
        solver: SolverBackend::Cg,
        solve_tol: 1e-5,
        newton_tol: 1e-2,
        max_newton: 15,
        ..Default::default()
    };
    // Engine-backed kernel through the SAME LaplaceGpc code path.
    let mut gpc_engine = LaplaceGpc::new(&ek, &y, cfg.clone());
    let fit_engine = gpc_engine.fit();

    let k_native = DenseKernel::new(RbfKernel::new(1.0, 10.0).gram(&x));
    let mut gpc_native = LaplaceGpc::new(&k_native, &y, cfg);
    let fit_native = gpc_native.fit();

    let (a, b) = (fit_engine.final_log_lik(), fit_native.final_log_lik());
    assert!(
        (a - b).abs() / b.abs() < 1e-3,
        "engine loglik {a} vs native {b}"
    );
}

#[test]
fn fused_engine_laplace_matches_generic_path() {
    let eng = engine();
    let (x32, y, x) = features();
    let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();

    // Fused driver (newton_stats + newton_update artifacts).
    let cfg = krr::runtime::laplace_engine::EngineLaplaceConfig {
        solve_tol: 1e-5,
        newton_tol: 1e-2,
        max_newton: 15,
        recycle: None,
    };
    let fused = krr::runtime::laplace_engine::fit(&ek, &y, &cfg).unwrap();

    // Generic native path for reference.
    let k_native = DenseKernel::new(RbfKernel::new(1.0, 10.0).gram(&x));
    let mut gpc = LaplaceGpc::new(
        &k_native,
        &y,
        LaplaceConfig {
            solver: SolverBackend::Cg,
            solve_tol: 1e-5,
            newton_tol: 1e-2,
            max_newton: 15,
            ..Default::default()
        },
    );
    let native = gpc.fit();
    let (a, b) = (fused.final_log_lik(), native.final_log_lik());
    assert!(
        (a - b).abs() / b.abs() < 1e-3,
        "fused {a} vs native {b}"
    );
    // Latent modes agree pointwise to f32-ish precision.
    for (u, v) in fused.f_hat.iter().zip(&native.f_hat) {
        assert!((u - v).abs() < 5e-2, "{u} vs {v}");
    }
}

#[test]
fn fused_engine_laplace_with_recycling_saves_iterations() {
    let eng = engine();
    let (x32, y, _x) = features();
    let ek = EngineKernel::from_features(eng, &x32, 2.5, 10.0).unwrap();
    let base = krr::runtime::laplace_engine::EngineLaplaceConfig {
        solve_tol: 1e-5,
        newton_tol: 1e-3,
        max_newton: 10,
        recycle: None,
    };
    let plain = krr::runtime::laplace_engine::fit(&ek, &y, &base).unwrap();
    let recycled = krr::runtime::laplace_engine::fit(
        &ek,
        &y,
        &krr::runtime::laplace_engine::EngineLaplaceConfig {
            recycle: Some(krr::solvers::recycle::RecycleConfig {
                k: 6,
                l: 10,
                ..Default::default()
            }),
            ..base
        },
    )
    .unwrap();
    let tail = |f: &krr::gp::laplace::LaplaceFit| {
        f.steps.iter().skip(1).map(|s| s.solver_iterations).sum::<usize>()
    };
    assert!(
        tail(&recycled) <= tail(&plain),
        "recycled {} > plain {}",
        tail(&recycled),
        tail(&plain)
    );
}

#[test]
fn engine_rejects_bad_shapes() {
    let eng = engine();
    let bad = Tensor::vec(vec![0.0; 3]);
    let err = eng.call(&format!("kmatvec_n{N}"), &[bad.clone(), bad]).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("shape"), "unexpected error: {msg}");
    assert!(eng.call("nonexistent", &[]).is_err());
}
