//! Kernel-level checks for the linalg substrate.
//!
//! The blocked/register-tiled kernels (`matvec_into`, `matmul`, the QR
//! and symmetric-eig factorizations) are compared against naive
//! triple-loop references on random matrices, including non-square and
//! degenerate 1×n shapes, and the sharded [`ParDenseOp`] is required to
//! reproduce the serial [`DenseOp`] to 1e-12 (it is in fact bitwise
//! identical: the shards compute the same per-row dots in the same
//! order).

use krr::linalg::eig::sym_eig;
use krr::linalg::mat::Mat;
use krr::linalg::qr::{mgs_orthonormalize, Qr};
use krr::linalg::vec_ops::norm2;
use krr::solvers::{DenseOp, ParDenseOp, SpdOperator};
use krr::util::pool::ThreadPool;
use krr::util::quickprop::forall;
use krr::util::rng::Rng;
use std::sync::Arc;

/// Naive y = A x (the reference the blocked kernel must match).
fn naive_matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; a.rows()];
    for i in 0..a.rows() {
        let mut acc = 0.0;
        for j in 0..a.cols() {
            acc += a[(i, j)] * x[j];
        }
        y[i] = acc;
    }
    y
}

/// Naive C = A B triple loop.
fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut acc = 0.0;
            for k in 0..a.cols() {
                acc += a[(i, k)] * b[(k, j)];
            }
            c[(i, j)] = acc;
        }
    }
    c
}

#[test]
fn blocked_matvec_matches_naive_reference() {
    forall("matvec_into == naive", 25, |g| {
        let rows = g.usize_in(1, 30);
        let cols = g.usize_in(1, 30);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let a = Mat::randn(rows, cols, &mut rng);
        let x = g.normal_vec(cols);
        let mut y = vec![0.0; rows];
        a.matvec_into(&x, &mut y);
        let want = naive_matvec(&a, &x);
        y.iter().zip(&want).all(|(u, v)| (u - v).abs() < 1e-10)
    });
}

#[test]
fn blocked_matvec_edge_shapes() {
    let mut rng = Rng::new(9);
    // 1×n row, n×1 column, 1×1 scalar.
    for (r, c) in [(1usize, 17usize), (17, 1), (1, 1)] {
        let a = Mat::randn(r, c, &mut rng);
        let x: Vec<f64> = (0..c).map(|i| i as f64 - 2.0).collect();
        let mut y = vec![0.0; r];
        a.matvec_into(&x, &mut y);
        let want = naive_matvec(&a, &x);
        for (u, v) in y.iter().zip(&want) {
            assert!((u - v).abs() < 1e-12, "{r}x{c}: {u} vs {v}");
        }
    }
}

#[test]
fn blocked_matmul_matches_naive_reference() {
    forall("matmul == naive", 20, |g| {
        let n = g.usize_in(1, 20);
        let m = g.usize_in(1, 20);
        let k = g.usize_in(1, 20);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let a = Mat::randn(n, m, &mut rng);
        let b = Mat::randn(m, k, &mut rng);
        a.matmul(&b).max_abs_diff(&naive_matmul(&a, &b)) < 1e-10
    });
}

#[test]
fn blocked_matmul_crosses_block_boundary() {
    // The matmul kernel blocks k in chunks of 64: exercise sizes
    // straddling the boundary.
    let mut rng = Rng::new(10);
    for k in [63usize, 64, 65, 130] {
        let a = Mat::randn(7, k, &mut rng);
        let b = Mat::randn(k, 5, &mut rng);
        assert!(
            a.matmul(&b).max_abs_diff(&naive_matmul(&a, &b)) < 1e-10,
            "k = {k}"
        );
    }
}

#[test]
fn qr_thin_q_is_orthonormal() {
    let mut rng = Rng::new(11);
    for (r, c) in [(40usize, 8usize), (512, 16), (12, 12), (5, 1)] {
        let a = Mat::randn(r, c, &mut rng);
        let q = Qr::factor(&a).thin_q();
        assert_eq!((q.rows(), q.cols()), (r, c));
        let qtq = q.t_matmul(&q);
        let dev = qtq.max_abs_diff(&Mat::identity(c));
        assert!(dev < 1e-10, "{r}x{c}: ‖QᵀQ − I‖_max = {dev}");
    }
}

#[test]
fn qr_reconstructs_the_input() {
    let mut rng = Rng::new(12);
    let a = Mat::randn(30, 6, &mut rng);
    let f = Qr::factor(&a);
    let qr = f.thin_q().matmul(&f.r());
    assert!(qr.max_abs_diff(&a) < 1e-10);
}

#[test]
fn mgs_produces_orthonormal_basis() {
    let mut rng = Rng::new(13);
    let a = Mat::randn(25, 6, &mut rng);
    let q = mgs_orthonormalize(&a, None, 1e-12);
    let qtq = q.t_matmul(&q);
    assert!(qtq.max_abs_diff(&Mat::identity(q.cols())) < 1e-10);
}

#[test]
fn sym_eig_pairs_satisfy_residual_bound() {
    forall("‖Av − λv‖ small on rand_spd", 8, |g| {
        let n = g.usize_in(2, 25);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let e = sym_eig(&a).unwrap();
        let scale = a.fro_norm().max(1.0);
        let mut ok = true;
        for j in 0..n {
            let v = e.vectors.col(j);
            let av = a.matvec(&v);
            let resid: Vec<f64> = av
                .iter()
                .zip(&v)
                .map(|(u, w)| u - e.values[j] * w)
                .collect();
            ok &= norm2(&resid) < 1e-8 * scale;
            ok &= (norm2(&v) - 1.0).abs() < 1e-10;
        }
        // Ascending order.
        ok && e.values.windows(2).all(|w| w[0] <= w[1] + 1e-12)
    });
}

#[test]
fn par_dense_op_matches_serial_to_1e12() {
    // ISSUE acceptance: ParDenseOp output bitwise-comparable (within
    // 1e-12) to serial DenseOp. Sizes straddle the serial threshold and
    // the ragged-last-block case; worker counts exercise 1..8 shards.
    let mut rng = Rng::new(14);
    for &n in &[64usize, 255, 256, 257, 512] {
        let a = Arc::new(Mat::rand_spd(n, 1e5, &mut rng));
        let x: Vec<f64> = (0..n).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
        let mut want = vec![0.0; n];
        DenseOp::new(&a).matvec(&x, &mut want);
        for workers in [1usize, 2, 3, 8] {
            let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(workers)));
            let mut got = vec![0.0; n];
            par.matvec(&x, &mut got);
            for (i, (u, v)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (u - v).abs() <= 1e-12,
                    "n={n} workers={workers} row {i}: {u} vs {v}"
                );
            }
        }
    }
}

#[test]
fn par_dense_op_shares_one_pool_across_operators() {
    // Several operators sharded over one pool — the coordinator's shape.
    let pool = Arc::new(ThreadPool::new(4));
    let mut rng = Rng::new(15);
    let x: Vec<f64> = (0..300).map(|i| (i % 4) as f64).collect();
    for seed in 0..3u64 {
        let _ = seed;
        let a = Arc::new(Mat::rand_spd(300, 1e3, &mut rng));
        let par = ParDenseOp::new(a.clone(), pool.clone());
        let got = par.matvec_alloc(&x);
        assert_eq!(got, a.matvec(&x));
    }
}
