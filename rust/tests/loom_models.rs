//! Exhaustive model checks of the coordinator's load-bearing concurrency
//! protocols, via [loom](https://docs.rs/loom).
//!
//! The whole file is gated on `--cfg loom`: the default build compiles it
//! to nothing (and needs no loom dependency). To run:
//!
//! ```text
//! cargo add loom@0.7 --dev --target 'cfg(loom)' -p krr   # CI does this; not vendored
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 cargo test --release -p krr --test loom_models
//! ```
//!
//! Each test explores **every** interleaving (up to the preemption bound)
//! of a small-N instance of one protocol, on the shimmed primitives from
//! `krr::util::sync` — the same types the shipped coordinator runs on.
//! Five protocols are pinned:
//!
//! 1. the `Slot` one-shot complete/poll state machine (the real type);
//! 2. the scheduled-flag one-entry-anywhere submit/dispatch handshake
//!    (mini-model of `SequenceHandle::enqueue` + `dispatch_one`);
//! 3. the busy→stamp→completed write order vs reverse snapshot read
//!    order behind `busy ≤ span × workers` (logical-clock model of
//!    `ServiceMetrics`);
//! 4. the all-of group-cancel set (the real `CancelToken`/`SolveControl`);
//! 5. the `ByteAccountant` settle-after-unlock `try_lock` eviction dance.
#![cfg(loom)]

use krr::coordinator::service::Slot;
use krr::coordinator::SolveReport;
use krr::solvers::{CancelToken, SolveControl, StopReason};
use krr::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use krr::util::sync::{lock_unpoisoned, Arc, Mutex};
use loom::thread;

fn stub_report() -> SolveReport {
    SolveReport {
        stop: StopReason::Converged,
        queue_seconds: 0.0,
        solve_seconds: 0.0,
        matvecs: 0,
        k_active: 0,
        group_size: 1,
        truncated_cols: 0,
        post_eviction: false,
        strategy: "",
        k_offered: 0,
        k_chosen: 0,
        predicted_savings: 0.0,
        realized_savings: 0.0,
    }
}

/// Protocol 1 — the `Slot` one-shot state machine (the REAL type from
/// `coordinator::service`): with a completer racing two non-blocking
/// pollers, the result is yielded at most once, never lost, and a
/// blocking `take` after the race drains whatever the pollers missed.
#[test]
fn slot_yields_result_exactly_once_under_racing_takers() {
    loom::model(|| {
        let slot = Slot::<u32>::new();
        let (s1, s2, s3) = (slot.clone(), slot.clone(), slot.clone());
        let completer = thread::spawn(move || s1.put(7, stub_report()));
        let p1 = thread::spawn(move || s2.try_take().map(|(v, _)| v));
        let p2 = thread::spawn(move || s3.try_take().map(|(v, _)| v));
        let a = p1.join().unwrap();
        let b = p2.join().unwrap();
        completer.join().unwrap();
        assert!(
            a.is_none() || b.is_none(),
            "one-shot slot yielded its result twice: {a:?} / {b:?}"
        );
        for got in [a, b].into_iter().flatten() {
            assert_eq!(got, 7, "a yielded result must be the completer's value");
        }
        if a.is_none() && b.is_none() {
            // Both pollers lost the race to the completion: the value
            // must still be there, exactly once, for a blocking take.
            let (v, _) = slot.take();
            assert_eq!(v, 7, "missed result must remain takeable");
            assert!(slot.try_take().is_none(), "slot must be empty after take");
        }
    });
}

/// A scheduled-flag sequence as in `service::SequenceState`: pending
/// task count and the one-entry-anywhere flag behind one mutex, plus the
/// (single) run queue the flag guards entry to. `enqueue` and
/// `dispatch_one` mirror `SequenceHandle::enqueue` /
/// `SolveService::dispatch_one` with the numerics stripped out.
struct MiniSeq {
    /// `(pending_tasks, scheduled)` — the state-lock half.
    state: Mutex<(usize, bool)>,
    /// The run queue (worker side). `true` entries represent this core;
    /// the invariant is at most one at any instant.
    queue: Mutex<Vec<()>>,
    dispatched: AtomicUsize,
}

impl MiniSeq {
    fn enqueue(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.0 += 1;
        if !st.1 {
            st.1 = true;
            // Entry is created strictly under the state lock that set
            // the flag — the handshake under test.
            let q = &mut *lock_unpoisoned(&self.queue);
            assert!(q.is_empty(), "scheduled flag admitted a second queue entry");
            q.push(());
        }
    }

    /// One worker turn: pop the core (if queued), consume one task,
    /// requeue-or-unschedule. Returns false when the queue was empty.
    fn dispatch_one(&self) -> bool {
        let popped = lock_unpoisoned(&self.queue).pop().is_some();
        if !popped {
            return false;
        }
        self.dispatched.fetch_add(1, Ordering::SeqCst);
        let mut st = lock_unpoisoned(&self.state);
        st.0 -= 1;
        if st.0 > 0 {
            let q = &mut *lock_unpoisoned(&self.queue);
            assert!(q.is_empty(), "requeue found the core already queued");
            q.push(());
        } else {
            st.1 = false;
        }
        true
    }
}

/// Protocol 2 — the scheduled-flag one-entry-anywhere handshake: two
/// concurrent submitters racing a dispatcher never produce a second
/// queue entry for the core, and never lose a task (every submitted task
/// is eventually dispatched, with the flag left clear).
#[test]
fn scheduled_flag_admits_one_queue_entry_and_loses_no_task() {
    loom::model(|| {
        let seq = Arc::new(MiniSeq {
            state: Mutex::new((0, false)),
            queue: Mutex::new(Vec::new()),
            dispatched: AtomicUsize::new(0),
        });
        let submitters: Vec<_> = (0..2)
            .map(|_| {
                let s = seq.clone();
                thread::spawn(move || s.enqueue())
            })
            .collect();
        let dispatcher = {
            let s = seq.clone();
            thread::spawn(move || {
                // Serve until both tasks are consumed; an empty pop just
                // means a submitter has not arrived yet.
                while s.dispatched.load(Ordering::SeqCst) < 2 {
                    if !s.dispatch_one() {
                        thread::yield_now();
                    }
                }
            })
        };
        for h in submitters {
            h.join().unwrap();
        }
        dispatcher.join().unwrap();
        assert_eq!(seq.dispatched.load(Ordering::SeqCst), 2, "a submitted task was lost");
        let st = lock_unpoisoned(&seq.state);
        assert_eq!(st.0, 0, "pending count must drain to zero");
        assert!(!st.1, "scheduled flag must clear once the queue drains");
        assert!(lock_unpoisoned(&seq.queue).is_empty(), "no orphan queue entry");
    });
}

/// Logical-clock model of the `ServiceMetrics` span/busy counters. Wall
/// time is replaced by a shared monotone counter; the writer follows the
/// real completion path's write order (busy, then span stamp, then
/// completed — all SeqCst), the reader follows `snapshot`'s REVERSE read
/// order (busy first, then completed/submitted, then stamps).
struct MiniMetrics {
    clock: AtomicU64,
    busy: AtomicU64,
    completed: AtomicU64,
    first: AtomicU64,
    last: AtomicU64,
}

/// Protocol 3 — the busy ≤ span × workers snapshot invariant. One worker
/// completes two back-to-back solves while a reader snapshots at every
/// possible interleaving point; with the submission count pre-set (the
/// submit path is not the racing part) the reader must never pair fresh
/// busy time with a stale span. This is exactly the PR 6 regression: the
/// old relaxed busy-LAST read let utilization exceed the worker count.
#[test]
fn snapshot_read_order_keeps_busy_within_span() {
    const SOLVES: u64 = 2;
    loom::model(|| {
        let m = Arc::new(MiniMetrics {
            clock: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            first: AtomicU64::new(0),
            last: AtomicU64::new(0),
        });
        let writer = {
            let m = m.clone();
            thread::spawn(move || {
                for _ in 0..SOLVES {
                    // Mirrors note_submitted → add_busy → note_completion.
                    let start = m.clock.fetch_add(1, Ordering::SeqCst) + 1;
                    let _ = m.first.compare_exchange(0, start, Ordering::SeqCst, Ordering::SeqCst);
                    let end = m.clock.fetch_add(1, Ordering::SeqCst) + 1;
                    m.busy.fetch_add(end - start, Ordering::SeqCst);
                    m.last.fetch_max(end, Ordering::SeqCst);
                    m.completed.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        let reader = {
            let m = m.clone();
            thread::spawn(move || {
                // snapshot(): busy FIRST, then counters, then stamps.
                let busy = m.busy.load(Ordering::SeqCst);
                let completed = m.completed.load(Ordering::SeqCst);
                let first = m.first.load(Ordering::SeqCst);
                let last = m.last.load(Ordering::SeqCst);
                if busy == 0 {
                    return; // nothing recorded yet — trivially within span
                }
                assert!(first != 0, "busy time recorded before any first-submit stamp");
                // In-flight solves extend the span end to "now", which is
                // at or after the true end of any busy already read.
                let span_end = if completed < SOLVES {
                    m.clock.fetch_add(1, Ordering::SeqCst) + 1
                } else {
                    last
                };
                assert!(
                    busy <= span_end.saturating_sub(first) + 1,
                    "busy {busy} exceeds span [{first}, {span_end}] on one worker"
                );
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
        // Quiescent snapshot is exact: 2 solves of 1 tick each inside
        // the [first, last] window.
        let busy = m.busy.load(Ordering::SeqCst);
        let span =
            m.last.load(Ordering::SeqCst).saturating_sub(m.first.load(Ordering::SeqCst)) + 1;
        assert!(busy <= span, "quiescent busy {busy} exceeds span {span}");
    });
}

/// Protocol 4 — the all-of group-cancel set, on the REAL
/// `CancelToken`/`SolveControl`: a group solve must not observe "all
/// cancelled" while any member still wants the result, every observation
/// of `cancelled` implies every member token is raised, and once both
/// members cancel, the group control is (and stays) cancelled.
#[test]
fn all_of_group_cancel_requires_every_member() {
    loom::model(|| {
        let a = CancelToken::new();
        let b = CancelToken::new();
        let group = SolveControl::all_of(vec![a.clone(), b.clone()], None);
        let (a2, b2) = (a.clone(), b.clone());
        let ha = thread::spawn(move || a2.cancel());
        let observer = {
            let (group, a, b) = (group.clone(), a.clone(), b.clone());
            thread::spawn(move || {
                // The kernel's per-iteration poll, at one arbitrary
                // point of the race.
                if group.is_cancelled() {
                    assert!(
                        a.is_cancelled() && b.is_cancelled(),
                        "group cancelled while a member still wanted the solve"
                    );
                }
            })
        };
        let hb = thread::spawn(move || b2.cancel());
        ha.join().unwrap();
        hb.join().unwrap();
        observer.join().unwrap();
        assert!(group.is_cancelled(), "both members cancelled ⇒ the group is cancelled");
    });
}

/// A sequence's evictable state for the accountant model: basis bytes
/// behind the per-sequence lock a dispatcher holds for the whole solve.
struct MiniBasis {
    bytes: Mutex<u64>,
}

/// Settle as `ByteAccountant::settle` does it: bookkeeping under the
/// ledger lock, then victim eviction strictly AFTER the ledger unlock,
/// and only via `try_lock` — a basis mid-solve is skipped, not waited
/// on. Returns the victims actually evicted.
fn mini_settle(ledger: &Mutex<Vec<usize>>, bases: &[MiniBasis]) -> Vec<usize> {
    let victims: Vec<usize> = lock_unpoisoned(ledger).clone();
    // Ledger guard dropped here — the settle-after-unlock half.
    let mut evicted = Vec::new();
    for &v in &victims {
        // The try_lock half: never block on a basis a solve may hold.
        if let Ok(mut b) = bases[v].bytes.try_lock() {
            if *b > 0 {
                *b = 0;
                evicted.push(v);
            }
        }
    }
    evicted
}

/// Protocol 5 — the ByteAccountant settle-after-unlock try_lock dance: a
/// dispatcher that calls settle WHILE holding its own sequence's basis
/// lock (exactly what `dispatch_one` does after a solve) races a second
/// settler. Every interleaving must terminate (the reversed lock order
/// ledger→basis vs basis→ledger would deadlock if either side blocked),
/// the in-flight basis is never evicted under its holder, and a basis
/// is never double-evicted.
#[test]
fn accountant_settle_never_deadlocks_or_evicts_held_basis() {
    loom::model(|| {
        let ledger = Arc::new(Mutex::new(vec![0usize, 1]));
        let bases = Arc::new([
            MiniBasis { bytes: Mutex::new(8) },
            MiniBasis { bytes: Mutex::new(8) },
        ]);
        let solver = {
            let (ledger, bases) = (ledger.clone(), bases.clone());
            thread::spawn(move || {
                // A dispatch turn on sequence 0: hold the basis across
                // the "solve", then settle while STILL holding it.
                let held = lock_unpoisoned(&bases[0].bytes);
                let before = *held;
                let evicted = mini_settle(&ledger, &bases[..]);
                assert!(!evicted.contains(&0), "settler evicted the basis it holds");
                assert_eq!(*held, before, "held basis mutated during settle");
                drop(held);
            })
        };
        let rival = {
            let (ledger, bases) = (ledger.clone(), bases.clone());
            thread::spawn(move || mini_settle(&ledger, &bases[..]))
        };
        solver.join().unwrap();
        let rival_evicted = rival.join().unwrap();
        // Sequence 0's basis was only evictable when the solver was not
        // holding it; sequence 1's was free throughout, so between the
        // two settles it is evicted exactly once.
        let final1 = *lock_unpoisoned(&bases[1].bytes);
        assert_eq!(final1, 0, "free victim must be evicted by some settle");
        assert!(rival_evicted.iter().filter(|&&v| v == 1).count() <= 1);
    });
}
