//! Block-first operator API + operator algebra integration suite.
//!
//! Pins the three load-bearing properties of the redesign:
//!
//! 1. **Column equivalence** — `apply_block` is bitwise the per-column
//!    `matvec` loop for every override in the repo (dense, parallel,
//!    Newton, regularized-kernel, and the algebra views over each),
//!    including ragged panel widths and k = 1, so no solver trajectory
//!    depends on whether its applications were batched.
//! 2. **Block routing** — the multi-vector hot paths (block-CG iteration,
//!    `Deflation::refresh`, diagonal probing) actually call `apply_block`
//!    and never loop `matvec` per column (asserted by operator
//!    apply-counts).
//! 3. **Accounting** — one block apply over k columns counts as k operator
//!    applications everywhere (`SolveResult::matvecs`,
//!    `BlockSolveResult::matvecs`, `ServiceMetrics::total_matvecs`), so
//!    service totals stay comparable with the pre-redesign numbers; and
//!    the plain-CG subset of a mixed service workload is bit-for-bit the
//!    direct `cg::solve` result.

use krr::coordinator::SolveService;
use krr::gp::laplace::{DenseKernel, LaplaceOperator};
use krr::gp::regression::RegularizedKernelOp;
use krr::linalg::mat::Mat;
use krr::solvers::blockcg;
use krr::solvers::defcg::Deflation;
use krr::solvers::recycle::RecycleConfig;
use krr::solvers::{
    self, DenseOp, LowRankUpdateOp, ParDenseOp, ShiftedOp, SolveSpec, SpdOperator, StopReason,
};
use krr::util::pool::ThreadPool;
use krr::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Wrapper that counts single-vector and block applications.
struct Counting<A> {
    inner: A,
    matvecs: AtomicUsize,
    block_applies: AtomicUsize,
    block_cols: AtomicUsize,
}

impl<A: SpdOperator> Counting<A> {
    fn new(inner: A) -> Self {
        Counting {
            inner,
            matvecs: AtomicUsize::new(0),
            block_applies: AtomicUsize::new(0),
            block_cols: AtomicUsize::new(0),
        }
    }
}

impl<A: SpdOperator> SpdOperator for Counting<A> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.matvecs.fetch_add(1, Ordering::Relaxed);
        self.inner.matvec(x, y);
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        self.block_applies.fetch_add(1, Ordering::Relaxed);
        self.block_cols.fetch_add(xs.cols(), Ordering::Relaxed);
        self.inner.apply_block(xs, ys);
    }

    fn diag(&self, out: &mut [f64]) {
        self.inner.diag(out);
    }
}

fn assert_block_is_matvec_loop(op: &dyn SpdOperator, tag: &str) {
    let n = op.n();
    let mut rng = Rng::new(99);
    for k in [1usize, 2, Mat::BLOCK_PANEL - 1, Mat::BLOCK_PANEL, Mat::BLOCK_PANEL + 1, 33] {
        let xs = Mat::randn(n, k, &mut rng);
        let mut want = Mat::zeros(n, k);
        let mut y = vec![0.0; n];
        for j in 0..k {
            op.matvec(&xs.col(j), &mut y);
            want.set_col(j, &y);
        }
        let mut ys = Mat::zeros(n, k);
        op.apply_block(&xs, &mut ys);
        assert_eq!(ys, want, "{tag}: apply_block != matvec loop at k={k}");
    }
}

#[test]
fn every_override_is_bitwise_the_matvec_loop() {
    let mut rng = Rng::new(1);
    let n = 300; // above ParDenseOp::PAR_THRESHOLD — the sharded path runs
    let a = Arc::new(Mat::rand_spd(n, 1e4, &mut rng));
    let pool = Arc::new(ThreadPool::new(3));

    let dense = DenseOp::new(&a);
    assert_block_is_matvec_loop(&dense, "DenseOp");

    let par = ParDenseOp::new(a.clone(), pool.clone());
    assert_block_is_matvec_loop(&par, "ParDenseOp");

    // GPC Newton operator over serial and pool-sharded dense kernels.
    let s: Vec<f64> = (0..n).map(|i| 0.3 + 0.001 * (i % 17) as f64).collect();
    let serial_k = DenseKernel::new((*a).clone());
    assert_block_is_matvec_loop(&LaplaceOperator::new(&serial_k, &s), "LaplaceOperator");
    let par_k = DenseKernel::parallel((*a).clone(), pool);
    assert_block_is_matvec_loop(&LaplaceOperator::new(&par_k, &s), "LaplaceOperator(par)");

    // Regularized kernel (GP regression).
    assert_block_is_matvec_loop(&RegularizedKernelOp::new(&a, 0.3), "RegularizedKernelOp");

    // Algebra views over a block-capable base.
    assert_block_is_matvec_loop(&ShiftedOp::new(&dense, 0.7), "ShiftedOp(DenseOp)");
    let u = Mat::randn(n, 3, &mut rng);
    assert_block_is_matvec_loop(&LowRankUpdateOp::new(&par, u), "LowRankUpdateOp(ParDenseOp)");
}

#[test]
fn deflation_refresh_uses_one_block_apply() {
    let mut rng = Rng::new(2);
    let n = 60;
    let a = Mat::rand_spd(n, 1e3, &mut rng);
    let w = krr::linalg::qr::Qr::factor(&Mat::randn(n, 6, &mut rng)).thin_q();
    let mut d = Deflation::new(w.clone(), Mat::zeros(n, 6));
    let op = Counting::new(DenseOp::new(&a));
    let cost = d.refresh(&op);
    assert_eq!(cost, 6, "refresh reports k applications");
    assert_eq!(op.matvecs.load(Ordering::Relaxed), 0, "no per-column matvec loop");
    assert_eq!(op.block_applies.load(Ordering::Relaxed), 1, "one block apply");
    assert_eq!(op.block_cols.load(Ordering::Relaxed), 6);
    assert!(d.aw.max_abs_diff(&a.matmul(&w)) < 1e-12);
}

#[test]
fn blockcg_iterates_through_apply_block_only() {
    let mut rng = Rng::new(3);
    let n = 50;
    let a = Mat::rand_spd(n, 1e3, &mut rng);
    let b = Mat::randn(n, 4, &mut rng);
    let op = Counting::new(DenseOp::new(&a));
    let r = blockcg::solve(&op, &b, 1e-9, 0);
    assert_eq!(r.stop, StopReason::Converged);
    assert_eq!(op.matvecs.load(Ordering::Relaxed), 0, "no single matvecs in the block loop");
    assert_eq!(op.block_applies.load(Ordering::Relaxed), r.block_matvecs);
    // The operator saw exactly the active panel widths the result bills:
    // rank-adaptive dropping means columns that converge early stop being
    // part of the panels, so the per-column total is bounded by (and for
    // synchronized columns equal to) the full-block count.
    assert_eq!(op.block_cols.load(Ordering::Relaxed), r.matvecs);
    assert_eq!(r.matvecs, r.col_matvecs.iter().sum::<usize>(), "per-column accounting");
    assert!(r.matvecs <= 4 * r.block_matvecs);
}

#[test]
fn recycled_sequence_refreshes_aw_in_blocks() {
    // Through the recycle manager with the (default) Refresh policy: the
    // second system's AW refresh must arrive as a block apply, and the CG
    // iteration itself as single matvecs — never a k-wide matvec loop.
    let mut rng = Rng::new(4);
    let n = 70;
    let a = Mat::rand_spd(n, 1e4, &mut rng);
    let b = vec![1.0; n];
    let spec = SolveSpec::defcg().with_tol(1e-8);
    let mut mgr = krr::solvers::recycle::RecycleManager::new(RecycleConfig {
        k: 6,
        l: 10,
        ..Default::default()
    });
    let op1 = Counting::new(DenseOp::new(&a));
    mgr.solve_next(&op1, &b, None, &spec);
    assert_eq!(op1.block_applies.load(Ordering::Relaxed), 0, "no basis to refresh yet");
    let k_active = mgr.k_active();
    assert!(k_active > 0, "first solve must have fed the basis");
    let op2 = Counting::new(DenseOp::new(&a));
    let r2 = mgr.solve_next(&op2, &b, None, &spec);
    assert_eq!(r2.stop, StopReason::Converged);
    let blocks = op2.block_applies.load(Ordering::Relaxed);
    let cols = op2.block_cols.load(Ordering::Relaxed);
    assert_eq!(blocks, 1, "AW refresh must be one block apply");
    assert_eq!(cols, k_active, "refresh spans the whole basis");
    // Accounting: the result's matvecs include the k refresh applications.
    assert_eq!(
        r2.matvecs,
        op2.matvecs.load(Ordering::Relaxed) + cols,
        "refresh counts as k applications in the solve total"
    );
}

#[test]
fn probe_diag_probes_in_panels() {
    let mut rng = Rng::new(5);
    let n = Mat::BLOCK_PANEL * 2 + 5; // ragged last panel
    let a = Mat::rand_spd(n, 100.0, &mut rng);
    let op = Counting::new(DenseOp::new(&a));
    let mut d = vec![0.0; n];
    krr::solvers::probe_diag(&op, &mut d);
    let want: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    assert_eq!(d, want, "panel probing must recover the exact diagonal");
    assert_eq!(op.matvecs.load(Ordering::Relaxed), 0);
    assert_eq!(op.block_applies.load(Ordering::Relaxed), 3, "⌈37/16⌉ panels");
    assert_eq!(op.block_cols.load(Ordering::Relaxed), n);
}

/// Owning dense operator for Arc'ing into the service.
struct OwnedDense(Mat);

impl SpdOperator for OwnedDense {
    fn n(&self) -> usize {
        self.0.rows()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.0.matvec_into(x, y);
    }
    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        self.0.block_matvec_into(xs, ys);
    }
    fn diag(&self, out: &mut [f64]) {
        self.0.diag_into(out);
    }
}

#[test]
fn mixed_operator_family_workload_through_one_service_sequence() {
    // The acceptance workload: plain, shifted, low-rank-updated, and
    // multi-RHS block requests on ONE sequence, with recycling active —
    // and the plain-CG subset bit-for-bit the direct kernel result.
    let mut rng = Rng::new(6);
    let n = 80;
    let a = Mat::rand_spd(n, 1e4, &mut rng);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
    let u = Mat::randn(n, 2, &mut rng);

    let svc = SolveService::new(2);
    let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
    let base: Arc<dyn SpdOperator + Send + Sync> = Arc::new(OwnedDense(a.clone()));
    let shifted: Arc<dyn SpdOperator + Send + Sync> =
        Arc::new(ShiftedOp::new(base.clone(), 0.5));
    let low_rank: Arc<dyn SpdOperator + Send + Sync> =
        Arc::new(LowRankUpdateOp::new(base.clone(), u.clone()));

    // 1) def-CG on the base (seeds the recycled basis).
    let t1 = seq.submit(base.clone(), b.clone(), None, SolveSpec::defcg().with_tol(1e-8));
    // 2) plain CG on the base — must stay bitwise the direct kernel.
    let t2 = seq.submit(base.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    // 3) def-CG on the σ-shifted view (recycles across the family).
    let t3 = seq.submit(shifted, b.clone(), None, SolveSpec::defcg().with_tol(1e-8));
    // 4) auto-Jacobi PCG on the low-rank-updated view (exact view diag).
    let t4 = seq.submit(
        low_rank,
        b.clone(),
        None,
        SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8),
    );
    // 5) multi-RHS block on the base.
    let mut rhs = Mat::zeros(n, 2);
    rhs.set_col(0, &b);
    rhs.set_col(1, &{
        let mut b2 = b.clone();
        b2.reverse();
        b2
    });
    let t5 = seq.submit_block(base.clone(), rhs, SolveSpec::blockcg().with_tol(1e-8));

    let r1 = t1.wait();
    let r2 = t2.wait();
    let r3 = t3.wait();
    let r4 = t4.wait();
    let r5 = t5.wait();
    for (i, r) in [&r1, &r2, &r3, &r4].into_iter().enumerate() {
        assert_eq!(r.stop, StopReason::Converged, "request {}", i + 1);
    }
    assert_eq!(r5.stop, StopReason::Converged);
    assert!(seq.k_active() > 0, "recycling must be active across the workload");

    // Correctness of the view solves against materialized references.
    let mut shifted_ref = a.clone();
    shifted_ref.add_diag(0.5);
    let res3 = {
        let ax = shifted_ref.matvec(&r3.x);
        let num: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
        num.sqrt() / krr::linalg::vec_ops::norm2(&b)
    };
    assert!(res3 <= 1e-7, "shifted view residual {res3}");
    let mut lr_ref = a.clone();
    lr_ref.add_in_place(&u.matmul(&u.transpose()));
    let res4 = {
        let ax = lr_ref.matvec(&r4.x);
        let num: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
        num.sqrt() / krr::linalg::vec_ops::norm2(&b)
    };
    assert!(res4 <= 1e-7, "low-rank view residual {res4}");

    // The plain-CG subset is bit-for-bit the direct kernel result — the
    // redesign may not move a single float on the pre-existing path.
    let direct = krr::solvers::cg::solve(
        &DenseOp::new(&a),
        &b,
        None,
        &SolveSpec::cg().with_tol(1e-8).with_store_l(10).cg_config(),
    );
    assert_eq!(r2.x, direct.x, "plain CG through the service must be unchanged");
    assert_eq!(r2.residuals, direct.residuals);

    // Aggregate accounting: the metrics total is exactly the sum of the
    // per-result matvec counts (block counted per column).
    let total: usize = [&r1, &r2, &r3, &r4].iter().map(|r| r.matvecs).sum::<usize>() + r5.matvecs;
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.total_matvecs, total);
    assert_eq!(snap.completed, 5);
    assert_eq!(seq.history().len(), 5);
}

#[test]
fn solve_block_and_single_dispatch_agree_on_accounting() {
    // A 1-column solve through the single-RHS BlockCg dispatch and the
    // same system through solve_block must report identical per-column
    // totals (the unit ServiceMetrics aggregates).
    let mut rng = Rng::new(7);
    let n = 40;
    let a = Mat::rand_spd(n, 1e3, &mut rng);
    let b: Vec<f64> = (0..n).map(|i| (i % 5) as f64 + 1.0).collect();
    let op = DenseOp::new(&a);
    let spec = SolveSpec::blockcg().with_tol(1e-9);
    let single = solvers::solve(&op, &b, &spec);
    let mut bm = Mat::zeros(n, 1);
    bm.set_col(0, &b);
    let block = solvers::solve_block(&op, &bm, &spec);
    assert_eq!(single.matvecs, block.matvecs);
    assert_eq!(block.matvecs, block.block_matvecs, "s = 1: one apply = one application");
}
