//! Multi-worker scheduler stress: the PR-5/6/7 lifecycle and recycling
//! invariants re-pinned under ≥4 scheduler workers, plus the new
//! fairness and cross-sequence billing guarantees.
//!
//! Everything here drives the public API only. Synchronization is via
//! `SolveService::pause` and operator-level flags, not sleeps, except
//! where a wall-clock bound is itself the property under test; the CI
//! stress job runs this suite single-threaded under a hard timeout so a
//! reintroduced deadlock fails fast instead of hanging.

use krr::coordinator::SolveService;
use krr::linalg::mat::Mat;
use krr::solvers::recycle::RecycleConfig;
use krr::solvers::{SolveSpec, SpdOperator, StopReason};
use krr::util::rng::Rng;
use krr::util::sync::lock_unpoisoned;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Owning dense operator.
struct OwnedDense(Mat);

impl SpdOperator for OwnedDense {
    fn n(&self) -> usize {
        self.0.rows()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.0.matvec_into(x, y);
    }
}

fn spd(n: usize, cond: f64, seed: u64) -> Arc<OwnedDense> {
    let mut rng = Rng::new(seed);
    Arc::new(OwnedDense(Mat::rand_spd(n, cond, &mut rng)))
}

/// Operator that records which (sequence tag, request tag) touched it
/// first — the order probe for FIFO-under-stealing.
struct TagOp {
    a: Mat,
    seq: usize,
    req: usize,
    log: Arc<Mutex<Vec<(usize, usize)>>>,
    logged: AtomicBool,
}

impl SpdOperator for TagOp {
    fn n(&self) -> usize {
        self.a.rows()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        if !self.logged.swap(true, Ordering::SeqCst) {
            lock_unpoisoned(&self.log).push((self.seq, self.req));
        }
        self.a.matvec_into(x, y);
    }
}

/// 8 sequences × 6 pipelined mixed-priority requests on 4 workers: every
/// solve converges, recycling still pays within each sequence, and the
/// service-wide accounting stays consistent (slots released, class
/// gauges drained, busy bounded by span × workers).
#[test]
fn multi_worker_pipelined_load_converges_with_sane_accounting() {
    let svc = SolveService::new(4);
    assert_eq!(svc.workers(), 4);
    let cfg = RecycleConfig { k: 6, l: 10, ..Default::default() };
    let n = 50;
    let seqs: Vec<_> = (0..8).map(|_| svc.open_sequence(cfg.clone())).collect();
    let ops: Vec<_> = (0..8).map(|s| spd(n, 1e4, 500 + s as u64)).collect();
    let b = vec![1.0; n];
    let mut futures = Vec::new();
    for r in 0..6 {
        for (s, seq) in seqs.iter().enumerate() {
            let mut spec = SolveSpec::defcg().with_tol(1e-8);
            if r % 3 == 0 {
                spec = spec.batch();
            }
            futures.push((s, seq.submit(ops[s].clone(), b.clone(), None, spec)));
        }
    }
    for (s, f) in futures {
        let r = f.wait();
        assert_eq!(r.stop, StopReason::Converged, "sequence {s}");
    }
    for (s, seq) in seqs.iter().enumerate() {
        let hist = seq.history();
        assert_eq!(hist.len(), 6, "sequence {s}");
        assert!(seq.k_active() > 0, "sequence {s} basis never warmed");
        // Identical systems within a sequence: whatever execution order
        // the two priority classes produced, the first-executed solve is
        // cold and the last-executed rides a warm basis — the history is
        // in execution order, so recycling must show there.
        assert!(
            hist.last().unwrap().iterations < hist.first().unwrap().iterations,
            "sequence {s}: recycling stopped paying under multi-worker dispatch"
        );
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.submitted, 48);
    assert_eq!(snap.completed, 48);
    assert_eq!(snap.queue_depth, 0, "all admission slots released");
    assert_eq!(snap.interactive_depth, 0);
    assert_eq!(snap.batch_depth, 0);
    assert!(snap.interactive_high_water >= 1);
    assert!(snap.batch_high_water >= 1);
    assert_eq!(snap.workers, 4);
    assert!(
        snap.busy_seconds <= snap.span_seconds * 4.0 + 1e-6,
        "busy {} exceeds span {} × 4 workers",
        snap.busy_seconds,
        snap.span_seconds
    );
    assert!(snap.utilization() <= 1.0 + 1e-9);
}

/// The anti-starvation pin, on ONE worker (the hard case: both
/// sequences share a dispatcher). Sequence A receives a sustained
/// stream of Interactive requests — refilled as they complete, so its
/// urgent flag never clears — while sequence B submits one Batch
/// request. The worker's periodic fair pop must still serve B within a
/// bounded number of dispatch turns: the batch future completes while
/// the interactive stream is still flowing.
#[test]
fn batch_completes_under_sustained_interactive_stream_across_sequences() {
    let svc = Arc::new(SolveService::new(1));
    let sa = svc.open_sequence(RecycleConfig::default());
    let sb = svc.open_sequence(RecycleConfig::default());
    let n = 35;
    let op_a = spd(n, 1e3, 900);
    let op_b = spd(n, 1e3, 901);
    let b = vec![1.0; n];
    let stop_feed = Arc::new(AtomicBool::new(false));
    let feeder = {
        let sa = sa.clone();
        let op_a = op_a.clone();
        let b = b.clone();
        let stop_feed = stop_feed.clone();
        std::thread::spawn(move || {
            // Keep ~8 interactive requests in flight in sequence A the
            // whole time; collect completions as we go.
            let spec = SolveSpec::cg().with_tol(1e-8);
            let mut inflight = std::collections::VecDeque::new();
            while !stop_feed.load(Ordering::SeqCst) {
                while inflight.len() < 8 {
                    inflight.push_back(sa.submit(op_a.clone(), b.clone(), None, spec.clone()));
                }
                if let Some(f) = inflight.pop_front() {
                    assert_eq!(f.wait().stop, StopReason::Converged);
                }
            }
            for f in inflight {
                assert_eq!(f.wait().stop, StopReason::Converged);
            }
        })
    };
    // Let the stream establish itself, then submit the batch request.
    while sa.history().is_empty() {
        std::thread::yield_now();
    }
    let tb = sb.submit(op_b, b, None, SolveSpec::cg().with_tol(1e-8).batch());
    let r = tb.wait_timeout(Duration::from_secs(60));
    // Stop the stream BEFORE asserting so a failure doesn't leak the
    // feeder thread into the rest of the suite.
    stop_feed.store(true, Ordering::SeqCst);
    feeder.join().unwrap();
    let r = r.expect("batch request starved by a sustained interactive stream in another sequence");
    assert_eq!(r.stop, StopReason::Converged);
    assert_eq!(sb.history().len(), 1);
}

/// FIFO within a class survives work-stealing: 3 sequences × 8 batch
/// requests on 4 workers (steals essentially guaranteed while queues
/// drain). Whatever worker runs a given solve, each sequence's requests
/// must reach their operators in submission order — a stolen core
/// dispatches from the same per-sequence queue.
#[test]
fn fifo_within_class_survives_stealing() {
    let svc = SolveService::new(4);
    let mut rng = Rng::new(910);
    let n = 40;
    let a = Mat::rand_spd(n, 1e3, &mut rng);
    let log: Arc<Mutex<Vec<(usize, usize)>>> = Arc::new(Mutex::new(Vec::new()));
    let seqs: Vec<_> = (0..3).map(|_| svc.open_sequence(RecycleConfig::default())).collect();
    let b = vec![1.0; n];
    let pause = svc.pause();
    let mut futures = Vec::new();
    for (s, seq) in seqs.iter().enumerate() {
        for r in 0..8 {
            let op = Arc::new(TagOp {
                a: a.clone(),
                seq: s,
                req: r,
                log: log.clone(),
                logged: AtomicBool::new(false),
            });
            futures.push(seq.submit(op, b.clone(), None, SolveSpec::cg().with_tol(1e-8).batch()));
        }
    }
    drop(pause);
    for f in futures {
        assert_eq!(f.wait().stop, StopReason::Converged);
    }
    let log = lock_unpoisoned(&log);
    assert_eq!(log.len(), 24);
    for s in 0..3 {
        let order: Vec<usize> = log.iter().filter(|(ls, _)| *ls == s).map(|&(_, r)| r).collect();
        assert_eq!(
            order,
            (0..8).collect::<Vec<_>>(),
            "sequence {s} ran out of submission order under stealing"
        );
    }
}

/// Cross-sequence billing under 4 workers: 8 sequences stage one block
/// request each on a shared operator `Arc`. Racing leaders may split
/// the population into several groups — that is allowed; what must hold
/// exactly is the billing invariant: per-ticket matvec shares sum to
/// the service total, and every ticket converges on its own columns.
#[test]
fn cross_sequence_billing_sums_exactly_under_four_workers() {
    let svc = SolveService::new(4);
    let mut rng = Rng::new(920);
    let n = 60;
    let a = Mat::rand_spd(n, 1e3, &mut rng);
    let x_true = Mat::randn(n, 2, &mut rng);
    let b = a.matmul(&x_true);
    let op: Arc<dyn SpdOperator + Send + Sync> = Arc::new(OwnedDense(a));
    let seqs: Vec<_> = (0..8).map(|_| svc.open_sequence(RecycleConfig::default())).collect();
    let pause = svc.pause();
    let spec = SolveSpec::blockcg().with_tol(1e-9);
    let futures: Vec<_> =
        seqs.iter().map(|s| s.submit_block(op.clone(), b.clone(), spec.clone())).collect();
    drop(pause);
    let mut billed = 0usize;
    for f in futures {
        let (r, rep) = f.wait_report();
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(rep.matvecs, r.matvecs, "report and result must agree per ticket");
        assert!(r.x.max_abs_diff(&x_true) < 1e-4, "each ticket gets its own exact columns");
        billed += r.matvecs;
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(
        billed, snap.total_matvecs,
        "per-ticket shares must sum exactly to the service total"
    );
    // Every solve landed in exactly one sequence's history (leaders),
    // and member sequences carry none.
    let hist_total: usize = seqs.iter().map(|s| s.history().len()).sum();
    let merged = snap.cross_seq_coalesced;
    assert_eq!(hist_total + merged, 8, "each ticket is either a leader's solve or a member");
    assert_eq!(snap.completed, 8);
}

/// Deadline-feeds-basis survives multi-worker dispatch: a mid-solve
/// deadline on one sequence returns a partial result that still warms
/// that sequence's basis, while 4 workers run other sequences.
#[test]
fn deadline_feeds_basis_under_four_workers() {
    struct SleepOp {
        a: Mat,
    }
    impl SpdOperator for SleepOp {
        fn n(&self) -> usize {
            self.a.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            std::thread::sleep(Duration::from_millis(2));
            self.a.matvec_into(x, y);
        }
    }
    let svc = SolveService::new(4);
    // Background traffic on other sequences while the deadline fires.
    let bg_seq = svc.open_sequence(RecycleConfig::default());
    let bg_op = spd(40, 1e3, 930);
    let bg: Vec<_> = (0..6)
        .map(|_| bg_seq.submit(bg_op.clone(), vec![1.0; 40], None, SolveSpec::cg().with_tol(1e-8)))
        .collect();
    let n = 90;
    let mut rng = Rng::new(931);
    let a = Mat::rand_spd(n, 1e6, &mut rng);
    let seq = svc.open_sequence(RecycleConfig { k: 8, l: 12, ..Default::default() });
    let slow = Arc::new(SleepOp { a: a.clone() });
    let spec = SolveSpec::defcg().with_tol(1e-15).with_deadline(Duration::from_millis(150));
    let (r, report) = seq.submit(slow, a.matvec(&vec![1.0; n]), None, spec).wait_report();
    assert_eq!(r.stop, StopReason::DeadlineExceeded, "stopped as {:?}", r.stop);
    assert!(r.iterations >= 1);
    assert!(report.k_active > 0, "the partial run must feed the basis");
    assert!(seq.k_active() > 0);
    for f in bg {
        assert_eq!(f.wait().stop, StopReason::Converged);
    }
    assert_eq!(svc.metrics().snapshot().deadline_exceeded, 1);
}

/// Byte-accountant settlement under 4 workers: a service-wide cap that
/// fits roughly one basis forces evictions while sequences settle
/// concurrently from different workers; every solve still converges and
/// the ledger stays consistent.
#[test]
fn byte_accountant_settles_under_four_workers() {
    let cap = 5_000;
    let svc = SolveService::with_byte_cap(4, SolveService::DEFAULT_QUEUE_CAP, cap);
    let cfg = RecycleConfig { k: 6, l: 10, ..Default::default() };
    let seqs: Vec<_> = (0..8).map(|_| svc.open_sequence(cfg.clone())).collect();
    let spec = SolveSpec::defcg().with_tol(1e-8);
    // Pipelined across all sequences: settlements race on purpose.
    let mut futures = Vec::new();
    for _round in 0..3 {
        for (i, seq) in seqs.iter().enumerate() {
            let n = 40 + 2 * i;
            let op = spd(n, 1e4, 940 + i as u64); // same system per sequence each round
            futures.push(seq.submit(op, vec![1.0; n], None, spec.clone()));
        }
    }
    for f in futures {
        assert_eq!(f.wait().stop, StopReason::Converged);
    }
    let snap = svc.metrics().snapshot();
    assert!(snap.basis_evictions > 0, "the global cap never evicted anything");
    assert!(snap.bytes_held > 0);
    for (i, seq) in seqs.iter().enumerate() {
        assert_eq!(seq.history().len(), 3, "sequence {i}");
    }
}

/// Hammer `snapshot` while 4 workers chew through 6 sequences: the
/// utilization invariant `busy ≤ span × workers` must hold on every
/// concurrent read, not just at quiescence.
#[test]
fn snapshot_utilization_bounded_under_concurrent_load() {
    let svc = Arc::new(SolveService::new(4));
    let cfg = RecycleConfig { k: 4, l: 6, ..Default::default() };
    let n = 50;
    let seqs: Vec<_> = (0..6).map(|_| svc.open_sequence(cfg.clone())).collect();
    let ops: Vec<_> = (0..6).map(|s| spd(n, 1e4, 950 + s as u64)).collect();
    let done = Arc::new(AtomicBool::new(false));
    let violations = Arc::new(AtomicUsize::new(0));
    let reader = {
        let svc = svc.clone();
        let done = done.clone();
        let violations = violations.clone();
        std::thread::spawn(move || {
            while !done.load(Ordering::SeqCst) {
                let snap = svc.metrics().snapshot();
                if snap.busy_seconds > snap.span_seconds * 4.0 + 1e-6 {
                    violations.fetch_add(1, Ordering::SeqCst);
                }
            }
        })
    };
    let mut futures = Vec::new();
    for _ in 0..8 {
        for (s, seq) in seqs.iter().enumerate() {
            futures.push(seq.submit(
                ops[s].clone(),
                vec![1.0; n],
                None,
                SolveSpec::defcg().with_tol(1e-10),
            ));
        }
    }
    for f in futures {
        assert_eq!(f.wait().stop, StopReason::Converged);
    }
    done.store(true, Ordering::SeqCst);
    reader.join().unwrap();
    assert_eq!(
        violations.load(Ordering::SeqCst),
        0,
        "snapshot reported busy > span × workers under concurrent load"
    );
}

/// One-entry-anywhere property test: 10k randomized
/// submit/steal/pause/requeue operations across 4 submitter threads and
/// 4 scheduler workers, with a dedicated auditor thread hammering
/// `SolveService::audit_scheduler` the whole time — a sequence core must
/// never be observed resident in two run queues at once. The same audit
/// is `debug_assert`ed inside the scheduler's requeue/putback paths, so
/// a debug-build run of this test also self-checks every mutation; loom
/// proves the handshake exhaustively at small N
/// (`rust/tests/loom_models.rs`), this test covers the full-size system
/// with real solves, steals and pauses.
#[test]
fn audit_never_sees_core_in_two_queues_across_10k_random_ops() {
    const OPS_PER_THREAD: usize = 2500; // × 4 threads = 10k ops
    const MAX_INFLIGHT: usize = 48;
    let svc = Arc::new(SolveService::new(4));
    let n = 8;
    // 12 sequences shared by all submitter threads: cross-thread
    // submissions to one sequence race enqueue against dispatch-requeue,
    // and the home-queue imbalance (12 homes on 4 workers, bursty
    // submission) keeps the steal path hot.
    let seqs: Vec<_> = (0..12)
        .map(|_| Arc::new(svc.open_sequence(RecycleConfig { k: 3, l: 4, ..Default::default() })))
        .collect();
    let ops: Vec<_> = (0..12).map(|s| spd(n, 1e2, 960 + s as u64)).collect();
    let done = Arc::new(AtomicBool::new(false));
    let auditor = {
        let svc = svc.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut audits = 0usize;
            while !done.load(Ordering::SeqCst) {
                svc.audit_scheduler().expect("one-entry-anywhere violated");
                audits += 1;
                std::thread::yield_now();
            }
            audits
        })
    };
    let submitters: Vec<_> = (0..4)
        .map(|t| {
            let svc = svc.clone();
            let seqs = seqs.clone();
            let ops = ops.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(7_000 + t as u64);
                let mut inflight = std::collections::VecDeque::new();
                for i in 0..OPS_PER_THREAD {
                    // Occasionally pause the whole scheduler across a
                    // burst of submissions: pops between the pause and
                    // its drop exercise the putback (front-requeue) path.
                    let pause = if rng.below(50) == 0 {
                        Some(svc.pause())
                    } else {
                        None
                    };
                    let burst = if pause.is_some() { 4 } else { 1 };
                    for _ in 0..burst {
                        let s = rng.below(seqs.len() as u64) as usize;
                        let spec = if rng.below(3) == 0 {
                            SolveSpec::cg().with_tol(1e-6).batch()
                        } else {
                            SolveSpec::cg().with_tol(1e-6)
                        };
                        inflight.push_back(seqs[s].submit(
                            ops[s].clone(),
                            vec![1.0; n],
                            None,
                            spec,
                        ));
                    }
                    drop(pause);
                    // Randomly drain a future mid-stream (keeps requeue
                    // and unschedule transitions flowing) and always
                    // bound the in-flight population.
                    if rng.below(4) == 0 || inflight.len() > MAX_INFLIGHT {
                        if let Some(f) = inflight.pop_front() {
                            assert_eq!(f.wait().stop, StopReason::Converged, "thread {t} op {i}");
                        }
                    }
                }
                for f in inflight {
                    assert_eq!(f.wait().stop, StopReason::Converged);
                }
            })
        })
        .collect();
    for s in submitters {
        s.join().unwrap();
    }
    done.store(true, Ordering::SeqCst);
    let audits = auditor.join().unwrap();
    assert!(audits > 0, "the auditor never ran");
    svc.audit_scheduler().expect("final audit");
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.submitted, snap.completed, "all 10k+ ops completed");
    assert!(snap.submitted >= 10_000);
}
