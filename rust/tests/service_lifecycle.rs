//! Request-lifecycle semantics of the admission-controlled solve
//! service, end to end through the public API: cancellation before and
//! during a solve, mid-solve deadlines (with the partial work feeding
//! recycling), graceful drain vs abort teardown, and the non-blocking
//! future surface.
//!
//! These tests synchronize on operator-level flags (parked matvecs), not
//! sleeps, so they are deterministic; the CI stress job additionally
//! runs them single-threaded under a hard timeout so a reintroduced
//! wait-forever deadlock fails fast instead of hanging the suite.

use krr::coordinator::{Shutdown, SolveService};
use krr::linalg::mat::Mat;
use krr::solvers::recycle::RecycleConfig;
use krr::solvers::{SolveSpec, SpdOperator, StopReason};
use krr::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Operator that parks every matvec until released, recording how many
/// applications started.
struct SlowOp {
    a: Mat,
    started: AtomicBool,
    release: AtomicBool,
    calls: AtomicUsize,
}

impl SlowOp {
    fn new(a: Mat) -> Arc<Self> {
        Arc::new(SlowOp {
            a,
            started: AtomicBool::new(false),
            release: AtomicBool::new(false),
            calls: AtomicUsize::new(0),
        })
    }

    fn wait_started(&self) {
        while !self.started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
    }

    fn release(&self) {
        self.release.store(true, Ordering::SeqCst);
    }
}

impl SpdOperator for SlowOp {
    fn n(&self) -> usize {
        self.a.rows()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.started.store(true, Ordering::SeqCst);
        while !self.release.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        self.a.matvec_into(x, y);
    }
}

/// Plain owned operator with an application counter.
struct CountingOp {
    a: Mat,
    calls: AtomicUsize,
}

impl CountingOp {
    fn new(a: Mat) -> Arc<Self> {
        Arc::new(CountingOp { a, calls: AtomicUsize::new(0) })
    }
}

impl SpdOperator for CountingOp {
    fn n(&self) -> usize {
        self.a.rows()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.a.matvec_into(x, y);
    }
}

fn spd(n: usize, cond: f64, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::rand_spd(n, cond, &mut rng)
}

#[test]
fn cancel_before_dequeue_never_runs_and_skips_history() {
    let n = 30;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let slow = SlowOp::new(spd(n, 100.0, 1));
    let counted = CountingOp::new(spd(n, 100.0, 2));
    let b = vec![1.0; n];
    // First request parks the (single) drainer inside its solve...
    let t1 = seq.submit(slow.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    slow.wait_started();
    // ...so the second request is provably still queued when we cancel.
    let t2 = seq.submit(counted.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    t2.cancel();
    slow.release();
    assert_eq!(t1.wait().stop, StopReason::Converged);
    let (r2, report) = t2.wait_report();
    assert_eq!(r2.stop, StopReason::Cancelled);
    assert_eq!(r2.iterations, 0);
    assert_eq!(r2.matvecs, 0);
    assert_eq!(
        counted.calls.load(Ordering::SeqCst),
        0,
        "a request cancelled before dequeue must never touch its operator"
    );
    assert_eq!(report.stop, StopReason::Cancelled);
    assert_eq!(report.solve_seconds, 0.0);
    // Never-run requests leave no trace in the sequence history.
    assert_eq!(seq.history().len(), 1);
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.queue_depth, 0);
}

#[test]
fn cancel_mid_solve_returns_within_one_operator_application() {
    // The acceptance pin: a cancel issued against a solve parked inside
    // its operator returns a Cancelled partial result without paying
    // more than the one in-flight application.
    let n = 40;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let slow = SlowOp::new(spd(n, 1e6, 3));
    let b = vec![1.0; n];
    let fut = seq.submit(slow.clone(), b, None, SolveSpec::cg().with_tol(1e-12));
    slow.wait_started();
    fut.cancel();
    let at_cancel = slow.calls.load(Ordering::SeqCst);
    slow.release();
    let (r, report) = fut.wait_report();
    assert_eq!(r.stop, StopReason::Cancelled, "stopped as {:?}", r.stop);
    assert!(
        slow.calls.load(Ordering::SeqCst) <= at_cancel + 1,
        "cancel must take effect within one operator application \
         ({} applications after the cancel)",
        slow.calls.load(Ordering::SeqCst) - at_cancel
    );
    assert_eq!(report.stop, StopReason::Cancelled);
    // Cancelled work is never absorbed: the sequence basis stays empty.
    assert_eq!(seq.k_active(), 0);
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.cancelled, 1);
}

#[test]
fn deadline_mid_solve_returns_partial_x_that_feeds_recycling() {
    // Deadline semantics through the service: a tight per-request budget
    // on a sleeping operator stops the solve as DeadlineExceeded with a
    // partial iterate whose A-norm error beats the start (CG's A-norm
    // descent is monotone, so the partial trace can only have improved),
    // and whose stored directions cut the iteration count of the next
    // system in the sequence.
    struct SleepOp {
        a: Mat,
        calls: AtomicUsize,
    }
    impl SpdOperator for SleepOp {
        fn n(&self) -> usize {
            self.a.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.calls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(2));
            self.a.matvec_into(x, y);
        }
    }
    let n = 90;
    let a = spd(n, 1e6, 4);
    let x_true: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
    let b = a.matvec(&x_true);
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig { k: 8, l: 12, ..Default::default() });
    let slow = Arc::new(SleepOp { a: a.clone(), calls: AtomicUsize::new(0) });
    // tol far below what ~75 sleepy iterations can reach on cond 1e6:
    // the deadline must fire mid-solve.
    let spec = SolveSpec::defcg()
        .with_tol(1e-15)
        .with_deadline(Duration::from_millis(150));
    let (r, report) = seq.submit(slow.clone(), b.clone(), None, spec).wait_report();
    assert_eq!(r.stop, StopReason::DeadlineExceeded, "stopped as {:?}", r.stop);
    assert!(r.iterations >= 1, "the budget allowed at least one iteration");
    assert_eq!(report.stop, StopReason::DeadlineExceeded);
    assert!(report.k_active > 0, "the partial run must feed the basis");
    // Partial x: strictly closer to the solution in A-norm than the
    // zero start.
    let a_err = |x: &[f64]| -> f64 {
        let e: Vec<f64> = x.iter().zip(&x_true).map(|(u, v)| u - v).collect();
        let ae = a.matvec(&e);
        e.iter().zip(&ae).map(|(u, v)| u * v).sum::<f64>().sqrt()
    };
    assert!(a_err(&r.x) < a_err(&vec![0.0; n]), "partial x must beat the start");
    // The residual trace is real (one entry per completed iteration).
    assert_eq!(r.residuals.len(), r.iterations + 1);
    // Next system (same matrix behind a fast operator, no deadline):
    // the deadline-fed basis must cut iterations vs a cold solve.
    let cold = krr::solvers::solve(
        &krr::solvers::DenseOp::new(&a),
        &b,
        &SolveSpec::defcg().with_tol(1e-8),
    );
    assert_eq!(cold.stop, StopReason::Converged);
    let fast = CountingOp::new(a.clone());
    let warm = seq
        .submit(fast, b, None, SolveSpec::defcg().with_tol(1e-8))
        .wait();
    assert_eq!(warm.stop, StopReason::Converged);
    assert!(
        warm.iterations < cold.iterations,
        "deadline-fed basis {} >= cold {}",
        warm.iterations,
        cold.iterations
    );
    assert_eq!(svc.metrics().snapshot().deadline_exceeded, 1);
}

#[test]
fn deadline_expired_in_queue_completes_without_running() {
    let n = 25;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let slow = SlowOp::new(spd(n, 100.0, 5));
    let counted = CountingOp::new(spd(n, 100.0, 6));
    let b = vec![1.0; n];
    let t1 = seq.submit(slow.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    slow.wait_started();
    // Queued behind the parked solve with an already-short budget: by
    // the time the drainer reaches it, the deadline has passed.
    let t2 = seq.submit(
        counted.clone(),
        b.clone(),
        None,
        SolveSpec::cg().with_tol(1e-8).with_deadline(Duration::from_millis(30)),
    );
    std::thread::sleep(Duration::from_millis(60)); // let the deadline lapse
    slow.release();
    assert_eq!(t1.wait().stop, StopReason::Converged);
    let r2 = t2.wait();
    assert_eq!(r2.stop, StopReason::DeadlineExceeded);
    assert_eq!(
        counted.calls.load(Ordering::SeqCst),
        0,
        "a request whose deadline lapsed in the queue must not run"
    );
    assert_eq!(seq.history().len(), 1, "never-run requests leave no history");
    assert_eq!(svc.metrics().snapshot().deadline_exceeded, 1);
}

#[test]
fn shutdown_drain_completes_queued_work_then_rejects() {
    let n = 30;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let slow = SlowOp::new(spd(n, 100.0, 7));
    let good = CountingOp::new(spd(n, 100.0, 8));
    let b = vec![1.0; n];
    let t1 = seq.submit(slow.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    slow.wait_started();
    let t2 = seq.submit(good.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    let t3 = seq.submit(good.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    // Unblock the in-flight solve shortly after the drain starts waiting.
    let release_thread = {
        let slow = slow.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            slow.release();
        })
    };
    svc.shutdown(Shutdown::Drain);
    release_thread.join().unwrap();
    // Drain ran everything that was accepted...
    assert_eq!(t1.wait().stop, StopReason::Converged);
    assert_eq!(t2.wait().stop, StopReason::Converged);
    assert_eq!(t3.wait().stop, StopReason::Converged);
    assert_eq!(seq.history().len(), 3, "queued work must complete under Drain");
    // ...and the service no longer admits work.
    let err = seq
        .try_submit(good, b, None, SolveSpec::cg().with_tol(1e-8))
        .unwrap_err();
    assert_eq!(err, krr::coordinator::SubmitError::ShuttingDown);
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.cancelled, 0);
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.queue_depth, 0);
}

#[test]
fn shutdown_abort_cancels_queued_and_inflight_work() {
    let n = 30;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let slow = SlowOp::new(spd(n, 1e6, 9));
    let counted = CountingOp::new(spd(n, 100.0, 10));
    let b = vec![1.0; n];
    let t1 = seq.submit(slow.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-12));
    slow.wait_started();
    let t2 = seq.submit(counted.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    let t3 = seq.submit(counted.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    // Abort blocks until idle; the in-flight solve only observes its
    // cancel once its parked matvec returns, so release it from aside.
    let release_thread = {
        let slow = slow.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            slow.release();
        })
    };
    svc.shutdown(Shutdown::Abort);
    release_thread.join().unwrap();
    // The in-flight solve was cancelled mid-iteration; the queued ones
    // never ran at all.
    assert_eq!(t1.wait().stop, StopReason::Cancelled);
    assert_eq!(t2.wait().stop, StopReason::Cancelled);
    assert_eq!(t3.wait().stop, StopReason::Cancelled);
    assert_eq!(
        counted.calls.load(Ordering::SeqCst),
        0,
        "Abort must cancel queued work without running it"
    );
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.cancelled, 3);
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.queue_depth, 0);
    // And nothing cancelled was absorbed into the recycle basis.
    assert_eq!(seq.k_active(), 0);
}

#[test]
fn poll_and_wait_timeout_are_nonblocking_while_running() {
    let n = 20;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let slow = SlowOp::new(spd(n, 100.0, 11));
    let fut = seq.submit(slow.clone(), vec![1.0; n], None, SolveSpec::cg().with_tol(1e-8));
    slow.wait_started();
    assert!(fut.poll().is_none(), "poll must not block on a running solve");
    assert!(
        fut.wait_timeout(Duration::from_millis(20)).is_none(),
        "wait_timeout must give up on a running solve"
    );
    slow.release();
    // Blocking wait still resolves after the failed poll attempts.
    assert_eq!(fut.wait().stop, StopReason::Converged);
}

#[test]
fn poll_yields_the_result_exactly_once() {
    let n = 20;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let op = CountingOp::new(spd(n, 100.0, 12));
    let fut = seq.submit(op, vec![1.0; n], None, SolveSpec::cg().with_tol(1e-8));
    // Spin-poll to completion.
    let r = loop {
        if let Some(r) = fut.poll() {
            break r;
        }
        std::thread::yield_now();
    };
    assert_eq!(r.stop, StopReason::Converged);
    assert!(fut.poll().is_none(), "the result is yielded exactly once");
}

#[test]
fn caller_supplied_cancel_token_is_the_futures_token() {
    // A spec built with with_cancel keeps that token through submission:
    // raising the caller's own handle cancels the queued request.
    let n = 25;
    let svc = SolveService::new(1);
    let seq = svc.open_sequence(RecycleConfig::default());
    let slow = SlowOp::new(spd(n, 100.0, 13));
    let counted = CountingOp::new(spd(n, 100.0, 14));
    let b = vec![1.0; n];
    let t1 = seq.submit(slow.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
    slow.wait_started();
    let token = krr::solvers::CancelToken::new();
    let t2 = seq.submit(
        counted.clone(),
        b.clone(),
        None,
        SolveSpec::cg().with_tol(1e-8).with_cancel(token.clone()),
    );
    token.cancel(); // the caller's handle, not the future's
    slow.release();
    assert_eq!(t1.wait().stop, StopReason::Converged);
    assert_eq!(t2.wait().stop, StopReason::Cancelled);
    assert_eq!(counted.calls.load(Ordering::SeqCst), 0);
}
