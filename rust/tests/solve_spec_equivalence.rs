//! `SolveSpec` equivalence suite.
//!
//! The unified API is a *re-plumbing*, not a re-derivation: for a fixed
//! SPD system, each [`Method`] dispatched through `solvers::solve` must
//! reproduce the legacy free-function result **bit-for-bit** (same float
//! sequence, so same iterates, residual trace, and stop reason). On top
//! of that, the newly-reachable composition (Jacobi + deflation) must
//! still satisfy the A-norm monotonicity property that
//! `solver_properties.rs` pins for plain CG — the optimality invariant
//! that justifies reading iteration counts as convergence progress.

use krr::linalg::cholesky::Cholesky;
use krr::linalg::eig::sym_eig;
use krr::linalg::mat::Mat;
use krr::linalg::vec_ops::dot;
use krr::solvers::cg::{self, CgConfig};
use krr::solvers::defcg::{self, Deflation};
use krr::solvers::{self, blockcg, pcg, DenseOp, Jacobi, SolveSpec, StopReason};
use krr::util::rng::Rng;
use std::sync::Arc;

fn fixed_system(n: usize, seed: u64, cond: f64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a = Mat::rand_spd(n, cond, &mut rng);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + ((i * 5) % 9) as f64).collect();
    (a, b)
}

/// Deflation basis from the exact top-k eigenvectors of A.
fn exact_deflation(a: &Mat, k: usize) -> Deflation {
    let e = sym_eig(a).unwrap();
    let n = a.rows();
    let mut w = Mat::zeros(n, k);
    for (dst, j) in ((n - k)..n).enumerate() {
        w.set_col(dst, &e.vectors.col(j));
    }
    let aw = a.matmul(&w);
    Deflation::new(w, aw)
}

fn assert_identical(api: &krr::solvers::SolveResult, legacy: &krr::solvers::SolveResult) {
    assert_eq!(api.stop, legacy.stop);
    assert_eq!(api.iterations, legacy.iterations);
    assert_eq!(api.matvecs, legacy.matvecs);
    assert_eq!(api.x, legacy.x, "solution vectors must be bit-identical");
    assert_eq!(api.residuals, legacy.residuals, "residual traces must match");
}

#[test]
fn cg_spec_reproduces_legacy_cg_bitwise() {
    let (a, b) = fixed_system(50, 1, 1e4);
    let op = DenseOp::new(&a);
    let spec = SolveSpec::cg().with_tol(1e-9).with_store_l(6);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = cg::solve(&op, &b, None, &spec.cg_config());
    assert_eq!(api.stop, StopReason::Converged);
    assert_identical(&api, &legacy);
    assert_eq!(api.stored.p, legacy.stored.p);
}

#[test]
fn pcg_spec_reproduces_legacy_pcg_bitwise() {
    // Badly scaled diagonal so the preconditioner actually does work.
    let mut rng = Rng::new(2);
    let n = 60;
    let base = Mat::rand_spd(n, 10.0, &mut rng);
    let scales: Vec<f64> = (0..n).map(|i| 10f64.powf((i % 5) as f64)).collect();
    let a = Mat::from_fn(n, n, |i, j| base[(i, j)] * scales[i].sqrt() * scales[j].sqrt());
    let b = vec![1.0; n];
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    let op = DenseOp::new(&a);

    let spec = SolveSpec::pcg()
        .with_precond(Arc::new(Jacobi::new(&diag)))
        .with_tol(1e-9);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = pcg::solve(&op, &b, &diag, None, &spec.cg_config());
    assert_eq!(api.stop, StopReason::Converged);
    assert_identical(&api, &legacy);

    // `with_jacobi` (operator-diagonal route) is the same preconditioner:
    // DenseOp::diag is exact, so this too must be bit-identical.
    let via_op = solvers::solve(&op, &b, &SolveSpec::pcg().with_jacobi(&op).with_tol(1e-9));
    assert_identical(&via_op, &legacy);
}

#[test]
fn defcg_spec_reproduces_legacy_defcg_bitwise() {
    let (a, b) = fixed_system(70, 3, 1e5);
    let op = DenseOp::new(&a);
    let defl = exact_deflation(&a, 6);
    let spec = SolveSpec::defcg()
        .with_deflation(defl.clone())
        .with_tol(1e-9)
        .with_store_l(8);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = defcg::solve(&op, &b, None, Some(&defl), &spec.cg_config());
    assert_eq!(api.stop, StopReason::Converged);
    assert_identical(&api, &legacy);
}

#[test]
fn blockcg_spec_reproduces_legacy_blockcg_bitwise() {
    let (a, b) = fixed_system(40, 4, 1e4);
    let op = DenseOp::new(&a);
    let spec = SolveSpec::blockcg().with_tol(1e-9);
    let api = solvers::solve(&op, &b, &spec);
    let mut bm = Mat::zeros(40, 1);
    bm.set_col(0, &b);
    let legacy = blockcg::solve(&op, &bm, 1e-9, 0);
    assert_eq!(api.stop, legacy.stop);
    assert_eq!(api.iterations, legacy.iterations);
    assert_eq!(api.matvecs, legacy.block_matvecs);
    assert_eq!(api.x, legacy.x.col(0));
    assert_eq!(api.residuals, legacy.residuals);
}

#[test]
fn solve_with_x0_matches_legacy_warm_start_bitwise() {
    let (a, b) = fixed_system(45, 5, 1e4);
    let op = DenseOp::new(&a);
    let x0: Vec<f64> = (0..45).map(|i| 0.1 * (i as f64)).collect();
    let spec = SolveSpec::cg().with_tol(1e-9);
    let api = solvers::solve_with_x0(&op, &b, &x0, &spec);
    let legacy = cg::solve(&op, &b, Some(&x0), &spec.cg_config());
    assert_identical(&api, &legacy);
}

#[test]
fn composed_jacobi_deflation_error_is_monotone_in_the_a_norm() {
    // The A-norm monotonicity property from solver_properties.rs, now for
    // the composed Jacobi+deflation kernel: each iterate minimizes the
    // A-norm error over a nested (deflation ⊕ preconditioned-Krylov)
    // space, so re-running to increasing iteration caps must produce a
    // non-increasing error sequence.
    let mut rng = Rng::new(7);
    let n = 48;
    let base = Mat::rand_spd(n, 1e2, &mut rng);
    let scales: Vec<f64> = (0..n).map(|i| 10f64.powf((i % 3) as f64)).collect();
    let a = Mat::from_fn(n, n, |i, j| base[(i, j)] * scales[i].sqrt() * scales[j].sqrt());
    let x_true: Vec<f64> = (0..n).map(|i| ((i * 3) % 11) as f64 - 5.0).collect();
    let b = a.matvec(&x_true);
    let x_star = Cholesky::factor(&a).unwrap().solve(&b);
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    let defl = exact_deflation(&a, 4);
    let op = DenseOp::new(&a);

    // tol 1e-12 is comfortably achievable at this conditioning; pushing
    // past the round-off floor (e.g. tol 1e-15) would let accumulated
    // rounding grow the error again, which is not what this property is
    // about — monotonicity holds up to convergence.
    let mut prev = f64::INFINITY;
    let mut converged = false;
    for cap in 1..=(2 * n) {
        let spec = SolveSpec::defcg()
            .with_deflation(defl.clone())
            .with_precond(Arc::new(Jacobi::new(&diag)))
            .with_tol(1e-12)
            .with_max_iters(cap);
        let r = solvers::solve(&op, &b, &spec);
        let e: Vec<f64> = r.x.iter().zip(&x_star).map(|(u, v)| u - v).collect();
        let ae = a.matvec(&e);
        let a_norm = dot(&e, &ae).max(0.0).sqrt();
        assert!(
            a_norm <= prev * (1.0 + 1e-8) + 1e-10,
            "A-norm error grew at cap {cap}: {prev} -> {a_norm}"
        );
        prev = a_norm;
        if r.stop == StopReason::Converged {
            converged = true;
            break;
        }
    }
    assert!(converged, "composed solve must converge within 2n caps (err {prev})");
}

#[test]
fn spec_equivalence_holds_under_nontrivial_knobs() {
    // The scalar knobs (max_iters, stall_window) must round-trip through
    // the spec identically too — same early stop, same trace.
    let (a, b) = fixed_system(64, 8, 1e8);
    let op = DenseOp::new(&a);
    let spec = SolveSpec::cg().with_tol(1e-14).with_max_iters(7);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = cg::solve(
        &op,
        &b,
        None,
        &CgConfig { tol: 1e-14, max_iters: 7, ..Default::default() },
    );
    assert_eq!(api.stop, StopReason::MaxIters);
    assert_identical(&api, &legacy);
}
