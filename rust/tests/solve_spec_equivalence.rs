//! `SolveSpec` equivalence suite.
//!
//! The unified API is a *re-plumbing*, not a re-derivation: for a fixed
//! SPD system, each [`Method`] dispatched through `solvers::solve` must
//! reproduce the legacy free-function result **bit-for-bit** (same float
//! sequence, so same iterates, residual trace, and stop reason). On top
//! of that, the newly-reachable composition (Jacobi + deflation) must
//! still satisfy the A-norm monotonicity property that
//! `solver_properties.rs` pins for plain CG — the optimality invariant
//! that justifies reading iteration counts as convergence progress.

use krr::linalg::cholesky::Cholesky;
use krr::linalg::eig::sym_eig;
use krr::linalg::mat::Mat;
use krr::linalg::vec_ops::dot;
use krr::solvers::cg::{self, CgConfig};
use krr::solvers::defcg::{self, Deflation};
use krr::solvers::{self, blockcg, pcg, DenseOp, Jacobi, SolveSpec, StopReason};
use krr::util::rng::Rng;
use std::sync::Arc;

fn fixed_system(n: usize, seed: u64, cond: f64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a = Mat::rand_spd(n, cond, &mut rng);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + ((i * 5) % 9) as f64).collect();
    (a, b)
}

/// Deflation basis from the exact top-k eigenvectors of A.
fn exact_deflation(a: &Mat, k: usize) -> Deflation {
    let e = sym_eig(a).unwrap();
    let n = a.rows();
    let mut w = Mat::zeros(n, k);
    for (dst, j) in ((n - k)..n).enumerate() {
        w.set_col(dst, &e.vectors.col(j));
    }
    let aw = a.matmul(&w);
    Deflation::new(w, aw)
}

fn assert_identical(api: &krr::solvers::SolveResult, legacy: &krr::solvers::SolveResult) {
    assert_eq!(api.stop, legacy.stop);
    assert_eq!(api.iterations, legacy.iterations);
    assert_eq!(api.matvecs, legacy.matvecs);
    assert_eq!(api.x, legacy.x, "solution vectors must be bit-identical");
    assert_eq!(api.residuals, legacy.residuals, "residual traces must match");
}

#[test]
fn cg_spec_reproduces_legacy_cg_bitwise() {
    let (a, b) = fixed_system(50, 1, 1e4);
    let op = DenseOp::new(&a);
    let spec = SolveSpec::cg().with_tol(1e-9).with_store_l(6);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = cg::solve(&op, &b, None, &spec.cg_config());
    assert_eq!(api.stop, StopReason::Converged);
    assert_identical(&api, &legacy);
    assert_eq!(api.stored.p, legacy.stored.p);
}

#[test]
fn pcg_spec_reproduces_legacy_pcg_bitwise() {
    // Badly scaled diagonal so the preconditioner actually does work.
    let mut rng = Rng::new(2);
    let n = 60;
    let base = Mat::rand_spd(n, 10.0, &mut rng);
    let scales: Vec<f64> = (0..n).map(|i| 10f64.powf((i % 5) as f64)).collect();
    let a = Mat::from_fn(n, n, |i, j| base[(i, j)] * scales[i].sqrt() * scales[j].sqrt());
    let b = vec![1.0; n];
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    let op = DenseOp::new(&a);

    let spec = SolveSpec::pcg()
        .with_precond(Arc::new(Jacobi::new(&diag)))
        .with_tol(1e-9);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = pcg::solve(&op, &b, &diag, None, &spec.cg_config());
    assert_eq!(api.stop, StopReason::Converged);
    assert_identical(&api, &legacy);

    // `with_jacobi` (operator-diagonal route) is the same preconditioner:
    // DenseOp::diag is exact, so this too must be bit-identical.
    let via_op = solvers::solve(&op, &b, &SolveSpec::pcg().with_jacobi(&op).with_tol(1e-9));
    assert_identical(&via_op, &legacy);
}

#[test]
fn defcg_spec_reproduces_legacy_defcg_bitwise() {
    let (a, b) = fixed_system(70, 3, 1e5);
    let op = DenseOp::new(&a);
    let defl = exact_deflation(&a, 6);
    let spec = SolveSpec::defcg()
        .with_deflation(defl.clone())
        .with_tol(1e-9)
        .with_store_l(8);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = defcg::solve(&op, &b, None, Some(&defl), &spec.cg_config());
    assert_eq!(api.stop, StopReason::Converged);
    assert_identical(&api, &legacy);
}

#[test]
fn blockcg_spec_reproduces_legacy_blockcg_bitwise() {
    let (a, b) = fixed_system(40, 4, 1e4);
    let op = DenseOp::new(&a);
    let spec = SolveSpec::blockcg().with_tol(1e-9);
    let api = solvers::solve(&op, &b, &spec);
    let mut bm = Mat::zeros(40, 1);
    bm.set_col(0, &b);
    let legacy = blockcg::solve(&op, &bm, 1e-9, 0);
    assert_eq!(api.stop, legacy.stop);
    assert_eq!(api.iterations, legacy.iterations);
    assert_eq!(api.matvecs, legacy.block_matvecs);
    assert_eq!(api.x, legacy.x.col(0));
    assert_eq!(api.residuals, legacy.residuals);
}

#[test]
fn solve_with_x0_matches_legacy_warm_start_bitwise() {
    let (a, b) = fixed_system(45, 5, 1e4);
    let op = DenseOp::new(&a);
    let x0: Vec<f64> = (0..45).map(|i| 0.1 * (i as f64)).collect();
    let spec = SolveSpec::cg().with_tol(1e-9);
    let api = solvers::solve_with_x0(&op, &b, &x0, &spec);
    let legacy = cg::solve(&op, &b, Some(&x0), &spec.cg_config());
    assert_identical(&api, &legacy);
}

#[test]
fn composed_jacobi_deflation_error_is_monotone_in_the_a_norm() {
    // The A-norm monotonicity property from solver_properties.rs, now for
    // the composed Jacobi+deflation kernel: each iterate minimizes the
    // A-norm error over a nested (deflation ⊕ preconditioned-Krylov)
    // space, so re-running to increasing iteration caps must produce a
    // non-increasing error sequence.
    let mut rng = Rng::new(7);
    let n = 48;
    let base = Mat::rand_spd(n, 1e2, &mut rng);
    let scales: Vec<f64> = (0..n).map(|i| 10f64.powf((i % 3) as f64)).collect();
    let a = Mat::from_fn(n, n, |i, j| base[(i, j)] * scales[i].sqrt() * scales[j].sqrt());
    let x_true: Vec<f64> = (0..n).map(|i| ((i * 3) % 11) as f64 - 5.0).collect();
    let b = a.matvec(&x_true);
    let x_star = Cholesky::factor(&a).unwrap().solve(&b);
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    let defl = exact_deflation(&a, 4);
    let op = DenseOp::new(&a);

    // tol 1e-12 is comfortably achievable at this conditioning; pushing
    // past the round-off floor (e.g. tol 1e-15) would let accumulated
    // rounding grow the error again, which is not what this property is
    // about — monotonicity holds up to convergence.
    let mut prev = f64::INFINITY;
    let mut converged = false;
    for cap in 1..=(2 * n) {
        let spec = SolveSpec::defcg()
            .with_deflation(defl.clone())
            .with_precond(Arc::new(Jacobi::new(&diag)))
            .with_tol(1e-12)
            .with_max_iters(cap);
        let r = solvers::solve(&op, &b, &spec);
        let e: Vec<f64> = r.x.iter().zip(&x_star).map(|(u, v)| u - v).collect();
        let ae = a.matvec(&e);
        let a_norm = dot(&e, &ae).max(0.0).sqrt();
        assert!(
            a_norm <= prev * (1.0 + 1e-8) + 1e-10,
            "A-norm error grew at cap {cap}: {prev} -> {a_norm}"
        );
        prev = a_norm;
        if r.stop == StopReason::Converged {
            converged = true;
            break;
        }
    }
    assert!(converged, "composed solve must converge within 2n caps (err {prev})");
}

#[test]
fn spec_equivalence_holds_under_nontrivial_knobs() {
    // The scalar knobs (max_iters, stall_window) must round-trip through
    // the spec identically too — same early stop, same trace.
    let (a, b) = fixed_system(64, 8, 1e8);
    let op = DenseOp::new(&a);
    let spec = SolveSpec::cg().with_tol(1e-14).with_max_iters(7);
    let api = solvers::solve(&op, &b, &spec);
    let legacy = cg::solve(
        &op,
        &b,
        None,
        &CgConfig { tol: 1e-14, max_iters: 7, ..Default::default() },
    );
    assert_eq!(api.stop, StopReason::MaxIters);
    assert_identical(&api, &legacy);
}

// ---- block-CG / single-RHS equivalence and robustness -------------------

/// One-column block from a vector.
fn one_col(b: &[f64]) -> Mat {
    let mut m = Mat::zeros(b.len(), 1);
    m.set_col(0, b);
    m
}

#[test]
fn s1_deflated_block_cg_matches_defcg_iteration_for_iteration() {
    // The block kernel's arithmetic contract: a one-column active block
    // runs defcg's scalar recurrences, so the deflated block solve and
    // def-CG must walk the SAME trajectory — iteration-for-iteration,
    // residual-for-residual — not merely the same Krylov theory.
    let (a, b) = fixed_system(60, 11, 1e4);
    let op = DenseOp::new(&a);
    let defl = exact_deflation(&a, 5);
    let cfg = CgConfig::with_tol(1e-9);
    let blk = blockcg::solve_spec(&op, &one_col(&b), None, Some(&defl), None, &cfg);
    let ref_run = defcg::solve(&op, &b, None, Some(&defl), &cfg);
    assert_eq!(blk.stop, StopReason::Converged);
    assert_eq!(
        blk.iterations, ref_run.iterations,
        "s=1 deflated block CG must match def-CG iteration-for-iteration"
    );
    assert_eq!(blk.residuals, ref_run.residuals, "identical residual trace");
    assert_eq!(blk.x.col(0), ref_run.x, "identical iterates");
    // Through the spec plumbing (deflation no longer ignored by block
    // requests): same result again.
    let spec = SolveSpec::blockcg().with_deflation(defl).with_tol(1e-9);
    let api = solvers::solve(&op, &b, &spec);
    assert_eq!(api.iterations, ref_run.iterations);
    assert_eq!(api.x, ref_run.x);
}

#[test]
fn s1_preconditioned_block_cg_matches_pcg_iteration_for_iteration() {
    // Same contract for the preconditioned recurrence (and the composed
    // Jacobi + deflation one).
    let mut rng = Rng::new(12);
    let n = 50;
    let base = Mat::rand_spd(n, 10.0, &mut rng);
    let scales: Vec<f64> = (0..n).map(|i| 10f64.powf((i % 4) as f64)).collect();
    let a = Mat::from_fn(n, n, |i, j| base[(i, j)] * scales[i].sqrt() * scales[j].sqrt());
    let b = vec![1.0; n];
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    let op = DenseOp::new(&a);
    let jac = Jacobi::new(&diag);
    let cfg = CgConfig::with_tol(1e-9);
    let blk = blockcg::solve_spec(&op, &one_col(&b), None, None, Some(&jac), &cfg);
    let ref_run = defcg::solve_precond(&op, &b, None, None, Some(&jac), &cfg);
    assert_eq!(blk.iterations, ref_run.iterations);
    assert_eq!(blk.x.col(0), ref_run.x);
    assert_eq!(blk.residuals, ref_run.residuals);

    let defl = exact_deflation(&a, 4);
    let blk = blockcg::solve_spec(&op, &one_col(&b), None, Some(&defl), Some(&jac), &cfg);
    let ref_run = defcg::solve_precond(&op, &b, None, Some(&defl), Some(&jac), &cfg);
    assert_eq!(blk.iterations, ref_run.iterations, "composed kernel must agree too");
    assert_eq!(blk.x.col(0), ref_run.x);
}

#[test]
fn mixed_convergence_block_converges_where_seed_kernel_stalled() {
    // The acceptance scenario: a block holding a duplicate column AND a
    // pre-converged column at tol 1e-10. The seed kernel either looped on
    // its QR least-squares fallback until MaxIters or never shrank the
    // block; the rank-adaptive kernel must return Converged with the
    // dropped columns riding free.
    let mut rng = Rng::new(13);
    let n = 60;
    let a = Mat::rand_spd(n, 1e4, &mut rng);
    let x_true = Mat::randn(n, 2, &mut rng);
    let bt = a.matmul(&x_true);
    let mut b = Mat::zeros(n, 4);
    b.set_col(0, &bt.col(0));
    b.set_col(1, &bt.col(1));
    b.set_col(2, &bt.col(0)); // duplicate of column 0
    b.set_col(3, &bt.col(1));
    let mut x0 = Mat::zeros(n, 4);
    x0.set_col(3, &x_true.col(1)); // column 3 starts converged
    let cfg = CgConfig { tol: 1e-10, ..Default::default() };
    let r = blockcg::solve_spec(&DenseOp::new(&a), &b, Some(&x0), None, None, &cfg);
    assert_eq!(r.stop, StopReason::Converged, "stopped as {:?}", r.stop);
    // True residuals all at tolerance.
    for j in 0..4 {
        let ax = a.matvec(&r.x.col(j));
        let res: f64 = ax
            .iter()
            .zip(&b.col(j))
            .map(|(u, v)| (u - v) * (u - v))
            .sum::<f64>()
            .sqrt();
        let bn = krr::linalg::vec_ops::norm2(&b.col(j));
        assert!(res / bn <= 1e-8, "col {j}: {}", res / bn);
    }
    // Column dropping did its job: the duplicate contributed no direction
    // after the initial residual apply, the pre-converged column froze.
    assert_eq!(r.col_matvecs[2], 1, "duplicate column pays only the x0 apply");
    assert_eq!(r.col_matvecs[3], 1, "pre-converged column pays only the x0 apply");
    assert!(r.matvecs < 4 * r.block_matvecs);
    assert_eq!(r.matvecs, r.col_matvecs.iter().sum::<usize>());
    assert!(!r.final_residual().is_nan());
}

#[test]
fn block_store_l_feeds_ritz_extraction_like_single_rhs() {
    // Block runs are recycling citizens: their stored panels must be
    // valid harmonic-Ritz inputs (normalized, AP consistent) and produce
    // a basis that actually deflates a follow-up solve.
    use krr::solvers::ritz::{extract, RitzConfig, RitzSelect};
    let mut rng = Rng::new(14);
    let n = 80;
    let a = Mat::rand_spd(n, 1e5, &mut rng);
    let b = Mat::randn(n, 4, &mut rng);
    let cfg = CgConfig { tol: 1e-8, store_l: 12, ..Default::default() };
    let run = blockcg::solve_spec(&DenseOp::new(&a), &b, None, None, None, &cfg);
    assert_eq!(run.stored.len(), 12);
    let (defl, vals) = extract(
        None,
        &run.stored,
        n,
        &RitzConfig { k: 8, select: RitzSelect::Largest, min_col_norm: 1e-12 },
    )
    .expect("block panels must extract");
    assert!(!vals.is_empty());
    let b2 = vec![1.0; n];
    let plain = cg::solve(&DenseOp::new(&a), &b2, None, &CgConfig::with_tol(1e-8));
    let deflated =
        defcg::solve(&DenseOp::new(&a), &b2, None, Some(&defl), &CgConfig::with_tol(1e-8));
    assert!(
        deflated.iterations < plain.iterations,
        "a block-fed basis must deflate: {} >= {}",
        deflated.iterations,
        plain.iterations
    );
}
