//! Seeded randomized property tests for the solver layer.
//!
//! Four invariants the paper's pipeline rests on:
//!
//! 1. CG agrees with the dense Cholesky reference on random SPD systems;
//! 2. def-CG with an *exact* invariant subspace really deflates those
//!    eigenvalues — the iteration count drops versus plain CG;
//! 3. `WᵀAW` stays SPD through [`RecycleManager`] basis updates (the
//!    projector `P_W = I − AW(WᵀAW)⁻¹Wᵀ` stays well-defined);
//! 4. the CG error is monotonically non-increasing in the A-norm — the
//!    optimality property that justifies reading iteration counts as
//!    convergence progress.
//!
//! All randomness flows through the seeded [`krr::util::quickprop`] /
//! [`krr::util::rng`] substrates: runs are reproducible bit-for-bit.

use krr::linalg::cholesky::Cholesky;
use krr::linalg::eig::sym_eig;
use krr::linalg::mat::Mat;
use krr::linalg::vec_ops::{dot, norm2};
use krr::solvers::cg::{self, CgConfig};
use krr::solvers::defcg::{self, Deflation};
use krr::solvers::recycle::{RecycleConfig, RecycleManager};
use krr::solvers::{DenseOp, SolveSpec, StopReason};
use krr::util::quickprop::forall;
use krr::util::rng::Rng;

#[test]
fn cg_solution_matches_dense_cholesky() {
    forall("CG == Cholesky on random SPD", 20, |g| {
        let n = g.usize_in(2, 40);
        let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e3));
        let b = g.normal_vec(n);
        let r = cg::solve(&DenseOp::new(&a), &b, None, &CgConfig::with_tol(1e-11));
        let want = Cholesky::factor(&a).unwrap().solve(&b);
        r.stop == StopReason::Converged
            && r.x.iter().zip(&want).all(|(u, v)| (u - v).abs() < 1e-6)
    });
}

/// Deflation basis from the exact top-k eigenvectors of A.
fn exact_invariant_deflation(a: &Mat, k: usize) -> Deflation {
    let e = sym_eig(a).unwrap();
    let n = a.rows();
    let mut w = Mat::zeros(n, k);
    for (dst, j) in ((n - k)..n).enumerate() {
        w.set_col(dst, &e.vectors.col(j));
    }
    let aw = a.matmul(&w);
    Deflation::new(w, aw)
}

#[test]
fn exact_invariant_subspace_deflates_top_eigenvalues() {
    // With the top-k eigenvectors deflated the effective condition number
    // drops from λ_n/λ_1 to λ_{n−k}/λ_1 (paper §2.1): iteration counts
    // must fall versus plain CG on every draw.
    for seed in [31u64, 32, 33, 34] {
        let mut rng = Rng::new(seed);
        let n = 70;
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let cfg = CgConfig::with_tol(1e-8);
        let plain = cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        assert_eq!(plain.stop, StopReason::Converged);
        let defl = exact_invariant_deflation(&a, 8);
        let deflated = defcg::solve(&DenseOp::new(&a), &b, None, Some(&defl), &cfg);
        assert_eq!(deflated.stop, StopReason::Converged);
        assert!(
            deflated.iterations < plain.iterations,
            "seed {seed}: deflated {} >= plain {}",
            deflated.iterations,
            plain.iterations
        );
        // And the answer is still right.
        let ax = a.matvec(&deflated.x);
        let res: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v) * (u - v)).sum();
        assert!(res.sqrt() / norm2(&b) < 1e-7);
    }
}

/// A slowly drifting sequence of SPD matrices — the Newton-loop shape.
fn drifting_sequence(n: usize, count: usize, seed: u64) -> Vec<Mat> {
    let mut rng = Rng::new(seed);
    let a0 = Mat::rand_spd(n, 1e4, &mut rng);
    let mut delta = Mat::randn(n, n, &mut rng);
    delta.symmetrize();
    delta.scale_in_place(1e-3 / n as f64);
    (0..count)
        .map(|i| {
            let mut a = a0.clone();
            let mut d = delta.clone();
            d.scale_in_place(1.0 / (1.0 + i as f64));
            a.add_in_place(&d);
            a.add_diag(1e-6);
            a
        })
        .collect()
}

#[test]
fn wtaw_stays_spd_through_recycle_updates() {
    for seed in [41u64, 42] {
        let n = 60;
        let seq = drifting_sequence(n, 5, seed);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let mut mgr = RecycleManager::new(RecycleConfig { k: 6, l: 10, ..Default::default() });
        for (i, a) in seq.iter().enumerate() {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
            assert_eq!(r.stop, StopReason::Converged, "system {i}");
            if let Some(d) = mgr.deflation() {
                assert!(d.k() > 0);
                // WᵀAW under the *current* operator must stay SPD — the
                // deflation projector divides by it.
                let aw = a.matmul(&d.w);
                let mut wtaw = d.w.t_matmul(&aw);
                wtaw.symmetrize();
                assert!(
                    Cholesky::factor(&wtaw).is_ok(),
                    "seed {seed}, system {i}: WᵀAW lost definiteness"
                );
            }
        }
        assert!(mgr.k_active() > 0);
    }
}

#[test]
fn cg_error_is_monotone_in_the_a_norm() {
    // CG minimizes the A-norm of the error over the growing Krylov space,
    // so ‖x* − x_j‖_A is non-increasing in j (the 2-norm residual is NOT
    // monotone — this is the invariant that actually holds). CG is
    // deterministic, so re-running to increasing iteration caps visits
    // the same iterates.
    let mut rng = Rng::new(7);
    let n = 48;
    // cond 1e2: CG's finite-termination phase completes well inside the
    // 2n-iteration budget even under round-off.
    let a = Mat::rand_spd(n, 1e2, &mut rng);
    let x_true: Vec<f64> = (0..n).map(|i| ((i * 3) % 11) as f64 - 5.0).collect();
    let b = a.matvec(&x_true);
    let x_star = Cholesky::factor(&a).unwrap().solve(&b);
    let mut prev = f64::INFINITY;
    for cap in 1..=(2 * n) {
        let cfg = CgConfig { tol: 1e-15, max_iters: cap, store_l: 0, ..Default::default() };
        let r = cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let e: Vec<f64> = r.x.iter().zip(&x_star).map(|(u, v)| u - v).collect();
        let ae = a.matvec(&e);
        let a_norm = dot(&e, &ae).max(0.0).sqrt();
        assert!(
            a_norm <= prev * (1.0 + 1e-8) + 1e-10,
            "A-norm error grew at iteration {cap}: {prev} -> {a_norm}"
        );
        prev = a_norm;
        if r.stop == StopReason::Converged {
            break;
        }
    }
    // The loop must have converged to (near) machine precision.
    assert!(prev < 1e-8, "final A-norm error {prev}");
}

#[test]
fn deflated_solve_trace_is_well_formed() {
    // Per-iteration residual trace sanity on the deflated solver: the
    // trace starts at the post-shift residual and ends below tolerance,
    // and the solution satisfies the system.
    forall("def-CG trace is well-formed", 10, |g| {
        let n = g.usize_in(10, 40);
        let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e4));
        let b = g.normal_vec(n);
        let k = g.usize_in(1, 4);
        let defl = exact_invariant_deflation(&a, k);
        let r = defcg::solve(&DenseOp::new(&a), &b, None, Some(&defl), &CgConfig::with_tol(1e-9));
        let last = *r.residuals.last().unwrap();
        r.stop == StopReason::Converged
            && r.residuals.len() == r.iterations + 1
            && last <= 1e-9
            && last.is_finite()
    });
}
