//! A small hand-rolled Rust lexer — just enough token structure for the
//! scope-aware rules, with one hard guarantee: **round-trip fidelity**.
//! Concatenating `Tok::text` over `lex(src)` reproduces `src` byte for
//! byte (property-tested against every file in the repo), so nothing the
//! downstream region model sees was silently dropped or invented.
//!
//! The lexer understands exactly the forms that break naive line
//! matchers: `//` and nested `/* /* */ */` comments, string literals
//! with escapes, raw strings `r#"..."#` (any hash depth, plus `b`/`br`
//! byte forms), char literals vs lifetimes (`'a'` vs `'a`), raw
//! identifiers (`r#match`), and numeric literals with enough greed to
//! not swallow `..` ranges. It does **not** try to be rustc: token
//! *kinds* beyond those are approximate, which is fine — the rules only
//! rely on the exact classification of comments, strings and idents.

/// Token classes. `Code` is the catch-all for punctuation/operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (the text distinguishes them).
    Ident,
    /// `'a` — never opens a char literal.
    Lifetime,
    /// `"…"`, `r#"…"#`, `b"…"` — contents are data, not code.
    Str,
    /// `'x'`, `'\n'`, `b'x'`.
    Char,
    /// Integer/float literal (suffixes included).
    Num,
    /// `// …` or `/* … */` (nested); `lint:allow` markers live here.
    Comment,
    /// Spaces, tabs, newlines.
    Whitespace,
    /// Everything else, one byte at a time (`{`, `}`, `[`, `#`, …).
    Punct,
}

/// One token: kind + the exact source slice + 1-based start line.
#[derive(Debug, Clone, Copy)]
pub struct Tok<'a> {
    pub kind: TokKind,
    pub text: &'a str,
    pub line: usize,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Tokenize `src`. Total function: any byte sequence produces a token
/// stream whose concatenation is the input (malformed source degrades to
/// `Punct` bytes, it never panics and never loses bytes).
pub fn lex(src: &str) -> Vec<Tok<'_>> {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < b.len() {
        let start = i;
        let start_line = line;
        let kind = match b[i] {
            c if c.is_ascii_whitespace() => {
                while i < b.len() && b[i].is_ascii_whitespace() {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
                TokKind::Whitespace
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                TokKind::Comment
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                i += 2;
                let mut depth = 1usize;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == b'\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
                TokKind::Comment
            }
            b'"' => {
                i = scan_string(b, i, &mut line);
                TokKind::Str
            }
            // Raw strings / byte strings / raw idents share prefixes with
            // plain identifiers, so resolve them before the ident arm.
            b'r' | b'b' if raw_or_byte_len(b, i).is_some() => {
                let (kind, end) = scan_prefixed(b, i, &mut line);
                i = end;
                kind
            }
            b'\'' => {
                // Char literal vs lifetime: `'` + ident-start + no close
                // within the literal window is a lifetime (`'a`,
                // `'outer`); anything with a closing `'` nearby is a
                // char literal (`'x'`, `'\n'`, `'\u{1F600}'`).
                match char_literal_len(b, i) {
                    Some(len) => {
                        for &c in &b[i..i + len] {
                            if c == b'\n' {
                                line += 1;
                            }
                        }
                        i += len;
                        TokKind::Char
                    }
                    None => {
                        i += 1;
                        while i < b.len() && is_ident_cont(b[i]) {
                            i += 1;
                        }
                        TokKind::Lifetime
                    }
                }
            }
            c if is_ident_start(c) => {
                while i < b.len() && is_ident_cont(b[i]) {
                    i += 1;
                }
                TokKind::Ident
            }
            c if c.is_ascii_digit() => {
                i = scan_number(b, i);
                TokKind::Num
            }
            _ => {
                i += 1;
                TokKind::Punct
            }
        };
        toks.push(Tok { kind, text: &src[start..i], line: start_line });
    }
    toks
}

/// Length of a char literal starting at the `'` at `i`, or `None` if it
/// is a lifetime. Handles `'\''`, `'\\'`, `'\u{…}'` (up to 10 bytes of
/// escape payload) and multibyte UTF-8 scalar literals.
fn char_literal_len(b: &[u8], i: usize) -> Option<usize> {
    debug_assert_eq!(b.get(i), Some(&b'\''));
    let body = i + 1;
    if body >= b.len() {
        return None;
    }
    if b[body] == b'\\' {
        // Escape form: the byte after `\` is payload even when it is a
        // quote (`'\''`), so the closing-quote search starts past it.
        if body + 2 > b.len() {
            return None;
        }
        let window = &b[body + 2..b.len().min(body + 12)];
        return window.iter().position(|&c| c == b'\'').map(|p| p + 4);
    }
    if is_ident_start(b[body]) {
        // `'a'` is a char literal only if the very next byte closes it;
        // `'abc` (no close) or `'a:` is a lifetime/label.
        return if b.get(body + 1) == Some(&b'\'') { Some(3) } else { None };
    }
    // Non-ident scalar (`'+'`, `' '`, multibyte `'é'`): scan to close.
    let window = &b[body..b.len().min(body + 8)];
    window.iter().position(|&c| c == b'\'').map(|p| p + 2)
}

/// If position `i` starts `r"`, `r#`(raw string or raw ident), `b"`,
/// `b'`, `br"`, `br#`, return the prefix length, else `None`.
fn raw_or_byte_len(b: &[u8], i: usize) -> Option<usize> {
    match b[i] {
        b'r' => match b.get(i + 1) {
            Some(b'"') | Some(b'#') => Some(1),
            _ => None,
        },
        b'b' => match b.get(i + 1) {
            Some(b'"') | Some(b'\'') => Some(1),
            Some(b'r') => match b.get(i + 2) {
                Some(b'"') | Some(b'#') => Some(2),
                _ => None,
            },
            _ => None,
        },
        _ => None,
    }
}

/// Scan a `r…`/`b…`-prefixed literal (or raw identifier) starting at
/// `i`; returns (kind, end index).
fn scan_prefixed(b: &[u8], i: usize, line: &mut usize) -> (TokKind, usize) {
    let p = raw_or_byte_len(b, i).expect("caller checked prefix");
    let mut j = i + p;
    match b.get(j) {
        Some(b'"') => (TokKind::Str, scan_string(b, j, line)),
        Some(b'\'') => match char_literal_len(b, j) {
            Some(len) => (TokKind::Char, j + len),
            None => (TokKind::Punct, j + 1),
        },
        Some(b'#') => {
            // Count hashes: raw string `r##"…"##` or raw ident `r#name`.
            let mut hashes = 0;
            while b.get(j) == Some(&b'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j) == Some(&b'"') {
                j += 1;
                // Scan to `"` followed by `hashes` `#`s.
                'outer: while j < b.len() {
                    if b[j] == b'\n' {
                        *line += 1;
                    }
                    if b[j] == b'"' {
                        let mut k = 0;
                        while k < hashes {
                            if b.get(j + 1 + k) != Some(&b'#') {
                                j += 1;
                                continue 'outer;
                            }
                            k += 1;
                        }
                        j += 1 + hashes;
                        return (TokKind::Str, j);
                    }
                    j += 1;
                }
                (TokKind::Str, j)
            } else if hashes == 1 && b.get(j).copied().is_some_and(is_ident_start) {
                // Raw identifier `r#match`.
                while j < b.len() && is_ident_cont(b[j]) {
                    j += 1;
                }
                (TokKind::Ident, j)
            } else {
                (TokKind::Punct, i + 1)
            }
        }
        _ => (TokKind::Punct, i + 1),
    }
}

/// Scan a plain `"…"` string starting at the quote at `i`; returns the
/// index just past the closing quote (or EOF on unterminated input).
fn scan_string(b: &[u8], i: usize, line: &mut usize) -> usize {
    debug_assert_eq!(b.get(i), Some(&b'"'));
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            b'\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j.min(b.len())
}

/// Scan a numeric literal: digits, `_`, hex/suffix alphanumerics, one
/// `.` only when followed by a digit (so `0..n` stays a range), and an
/// exponent sign (`1e-3`).
fn scan_number(b: &[u8], i: usize) -> usize {
    let mut j = i;
    let mut seen_dot = false;
    while j < b.len() {
        let c = b[j];
        if c.is_ascii_alphanumeric() || c == b'_' {
            // `1e-3` / `1E+7`: consume the sign with the exponent.
            if (c == b'e' || c == b'E')
                && !b[i..j].iter().any(|&x| x == b'x' || x == b'b' || x == b'o')
                && matches!(b.get(j + 1), Some(b'+') | Some(b'-'))
                && b.get(j + 2).is_some_and(|d| d.is_ascii_digit())
            {
                j += 2;
            }
            j += 1;
        } else if c == b'.' && !seen_dot && b.get(j + 1).is_some_and(|d| d.is_ascii_digit()) {
            seen_dot = true;
            j += 1;
        } else {
            break;
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(src: &str) -> Vec<Tok<'_>> {
        let toks = lex(src);
        let rebuilt: String = toks.iter().map(|t| t.text).collect();
        assert_eq!(rebuilt, src, "lexer round-trip failed");
        toks
    }

    fn kinds_of(src: &str) -> Vec<(TokKind, String)> {
        roundtrip(src)
            .into_iter()
            .filter(|t| t.kind != TokKind::Whitespace)
            .map(|t| (t.kind, t.text.to_string()))
            .collect()
    }

    #[test]
    fn idents_keywords_puncts() {
        let ks = kinds_of("fn foo(x: u8) { x + 1 }");
        assert_eq!(ks[0], (TokKind::Ident, "fn".into()));
        assert_eq!(ks[1], (TokKind::Ident, "foo".into()));
        assert!(ks.iter().any(|k| *k == (TokKind::Punct, "{".into())));
        assert!(ks.iter().any(|k| *k == (TokKind::Num, "1".into())));
    }

    #[test]
    fn comments_line_and_nested_block() {
        let ks = kinds_of("a // tail .unwrap()\nb /* x /* y */ z */ c");
        assert_eq!(ks[0].0, TokKind::Ident);
        assert_eq!(ks[1], (TokKind::Comment, "// tail .unwrap()".into()));
        assert_eq!(ks[3], (TokKind::Comment, "/* x /* y */ z */".into()));
        assert_eq!(ks[4], (TokKind::Ident, "c".into()));
    }

    #[test]
    fn strings_with_escapes_and_embedded_slashes() {
        let ks = kinds_of(r#"let u = "https://x\" // not a comment"; y"#);
        assert!(ks.iter().any(|k| k.0 == TokKind::Str));
        assert_eq!(ks.last().unwrap(), &(TokKind::Ident, "y".into()));
    }

    #[test]
    fn raw_strings_any_hash_depth_and_byte_strings() {
        let ks = kinds_of(r###"let s = r#"has "quotes" and \ "#; t"###);
        assert!(ks.iter().any(|k| k.0 == TokKind::Str && k.1.starts_with("r#")));
        assert_eq!(ks.last().unwrap(), &(TokKind::Ident, "t".into()));
        let ks = kinds_of(r#"let b = b"bytes"; let r = br#"raw"# ; u"#);
        assert_eq!(ks.iter().filter(|k| k.0 == TokKind::Str).count(), 2);
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let ks = kinds_of("fn f<'a>(c: char) { if c == '\"' { } let q = 'x'; 'outer: loop {} }");
        assert!(ks.iter().any(|k| *k == (TokKind::Lifetime, "'a".into())));
        assert!(ks.iter().any(|k| *k == (TokKind::Char, "'\"'".into())));
        assert!(ks.iter().any(|k| *k == (TokKind::Char, "'x'".into())));
        assert!(ks.iter().any(|k| *k == (TokKind::Lifetime, "'outer".into())));
    }

    #[test]
    fn escaped_char_literals() {
        let ks = kinds_of(r"let a = '\n'; let b = '\''; let c = '\u{1F600}';");
        assert_eq!(ks.iter().filter(|k| k.0 == TokKind::Char).count(), 3);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let ks = kinds_of("for i in 0..n { let x = 1.5e-3; let y = 0xFFu32; }");
        assert!(ks.iter().any(|k| *k == (TokKind::Num, "0".into())));
        assert!(ks.iter().any(|k| *k == (TokKind::Num, "1.5e-3".into())));
        assert!(ks.iter().any(|k| *k == (TokKind::Num, "0xFFu32".into())));
    }

    #[test]
    fn raw_identifier() {
        let ks = kinds_of("let r#type = 3;");
        assert!(ks.iter().any(|k| *k == (TokKind::Ident, "r#type".into())));
    }

    #[test]
    fn line_numbers_track_newlines_everywhere() {
        let src = "a\n\"two\nline\"\n/* c\nc */ b";
        let toks = roundtrip(src);
        let b_tok = toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b_tok.line, 5);
    }

    #[test]
    fn total_on_malformed_input() {
        // Unterminated constructs must not panic or lose bytes.
        roundtrip("let s = \"unterminated");
        roundtrip("let c = '");
        roundtrip("/* never closed");
        roundtrip("r###\"never closed");
    }
}
