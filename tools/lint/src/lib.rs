//! Repo-invariant lint engine: mechanical enforcement of the rules
//! reviewers previously policed by hand (see DESIGN.md § Correctness
//! tooling for the rule table and rationale).
//!
//! Since PR 10 the engine is token- and scope-aware: a hand-rolled
//! lexer ([`lexer`], round-trip byte-exact) feeds a brace-tracking
//! region model ([`regions`]) that knows function boundaries, loop
//! bodies and `#[cfg(test)]` spans, so rules can say "no panic token in
//! a *non-test coordinator fn*" or "no allocation in a *solver
//! iteration loop*" instead of over-approximating per line. It is still
//! deliberately not AST-based: every rule is a *surface* invariant over
//! token text in a region, which keeps the tool dependency-free and
//! sub-second. Anything needing type knowledge is written so the cheap
//! approximation over-approximates and the `allow.list` carries the
//! sanctioned exceptions; every suppression is a reviewed line in that
//! file rather than an invisible non-match — and a suppression that
//! stops matching anything is itself an error (stale-suppression),
//! so exceptions cannot outlive the code they excused.
//!
//! Escape hatches, in precedence order:
//!
//! 1. an inline `lint:allow(rule-id)` marker anywhere on the raw line
//!    (for one-off sites whose justification belongs next to the code);
//! 2. an `allow.list` entry `rule-id path-suffix :: substring` (for
//!    policy-level exceptions, reviewed centrally);
//! 3. `skip_tests` rules ignore `#[test]` / `#[cfg(test)]` regions.

use std::fmt;
use std::path::{Path, PathBuf};

pub mod lexer;
pub mod regions;

use regions::NONINDEX_KEYWORDS;

/// How a rule selects the lines (or functions) it inspects.
pub enum RuleKind {
    /// Plain line predicate over comment/string-stripped text, applied
    /// everywhere the rule's scope and `skip_tests` admit.
    Line(fn(&str) -> bool),
    /// Line predicate restricted to non-test code in the scoped files —
    /// the coordinator "dispatch path" region.
    DispatchLine(fn(&str) -> bool),
    /// Line predicate restricted to `for`/`while`/`loop` bodies of the
    /// scoped files — the solver per-iteration region.
    HotLoopLine(fn(&str) -> bool),
    /// Function-level audit: every `.apply(`/`.apply_block(` call site
    /// must sit in a fn whose body also touches a matvec counter.
    MatvecBilling,
}

/// One lint rule: what to match plus where it applies.
pub struct Rule {
    /// Stable kebab-case identifier (used in `allow.list` and in the
    /// inline `lint:allow(...)` marker).
    pub id: &'static str,
    /// One-line explanation printed with every finding, stating the fix.
    pub message: &'static str,
    /// Path substrings (with `/` separators, relative to the scanned
    /// root) this rule applies to; empty = the whole tree.
    pub scopes: &'static [&'static str],
    /// Skip `#[test]` fns and `#[cfg(test)]`-gated regions.
    pub skip_tests: bool,
    pub kind: RuleKind,
}

/// One rule violation at a specific `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: &'static str,
    /// The offending line, trimmed (for the human reading the log).
    pub text: String,
    /// Innermost enclosing named function ("" at module scope).
    pub function: String,
    /// Region kind: "loop", "fn", "test" or "file".
    pub region: &'static str,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}]", self.path, self.line, self.rule)?;
        if self.function.is_empty() {
            write!(f, " ({})", self.region)?;
        } else {
            write!(f, " (fn {}, {})", self.function, self.region)?;
        }
        write!(f, " {}\n    {}", self.message, self.text)
    }
}

fn panic_tokens(l: &str) -> bool {
    l.contains(".unwrap()")
        || l.contains(".expect(")
        || l.contains("panic!")
        || l.contains("unreachable!")
        || l.contains("todo!(")
        || l.contains("unimplemented!")
}

fn alloc_tokens(l: &str) -> bool {
    l.contains("Vec::new")
        || l.contains("vec![")
        || l.contains(".clone()")
        || l.contains(".collect(")
        || l.contains(".collect::<")
}

fn lossy_cast(l: &str) -> bool {
    l.contains(" as f32") || l.contains(" as f64")
}

/// Bare slice/array indexing: a `[` directly following an identifier
/// (that is not a keyword introducing an array type/pattern/literal) or
/// a closing `)` / `]`. `#[attr]`, `vec![…]`, `let [a, b] = …`,
/// `[0u8; 8]` and `Vec<[f64; 4]>` all stay clean.
fn bare_index(l: &str) -> bool {
    let b = l.as_bytes();
    for i in 1..b.len() {
        if b[i] != b'[' {
            continue;
        }
        let p = b[i - 1];
        if p == b')' || p == b']' {
            return true;
        }
        if p.is_ascii_alphanumeric() || p == b'_' {
            let mut s = i;
            while s > 0 && (b[s - 1].is_ascii_alphanumeric() || b[s - 1] == b'_') {
                s -= 1;
            }
            let word = &l[s..i];
            let numeric = word.bytes().next().is_some_and(|c| c.is_ascii_digit());
            if !numeric && !NONINDEX_KEYWORDS.contains(&word) {
                return true;
            }
        }
    }
    false
}

/// The repo's rule set. IDs are load-bearing: `allow.list`, inline
/// markers and the self-test fixtures all refer to them.
pub fn default_rules() -> Vec<Rule> {
    vec![
        Rule {
            id: "float-sort-unwrap",
            message: "float comparison via partial_cmp(..).unwrap() panics on NaN — \
                      use total_cmp (and decide where NaN should sort)",
            scopes: &[],
            skip_tests: false,
            kind: RuleKind::Line(|l| l.contains("partial_cmp") && l.contains(".unwrap()")),
        },
        Rule {
            id: "bare-lock-unwrap",
            message: "bare .lock()/.read()/.write().unwrap() poisons the caller after a \
                      panic elsewhere — use util::sync::lock_unpoisoned (it recovers and \
                      logs the call site)",
            scopes: &[],
            skip_tests: false,
            kind: RuleKind::Line(|l| {
                l.contains(".lock().unwrap()")
                    || l.contains(".read().unwrap()")
                    || l.contains(".write().unwrap()")
            }),
        },
        Rule {
            id: "relaxed-ordering",
            message: "Ordering::Relaxed on coordinator state read by snapshot() breaks the \
                      busy ≤ span × workers invariant — use SeqCst (advisory hints go in \
                      allow.list)",
            scopes: &["coordinator/scheduler.rs", "coordinator/service.rs"],
            skip_tests: true,
            kind: RuleKind::Line(|l| l.contains("Ordering::Relaxed")),
        },
        Rule {
            id: "std-sync-in-shimmed",
            message: "shimmed modules must reach sync/thread primitives through util::sync \
                      so the loom build model-checks the shipped code",
            scopes: &["coordinator/scheduler.rs", "coordinator/service.rs", "solvers/control.rs"],
            skip_tests: true,
            kind: RuleKind::Line(|l| l.contains("std::sync") || l.contains("std::thread")),
        },
        Rule {
            id: "instant-in-solver",
            message: "Instant::now() inside solver code is a per-iteration syscall in the hot \
                      loop — time at kernel entry only (sanctioned sites live in allow.list)",
            scopes: &["solvers/"],
            skip_tests: true,
            kind: RuleKind::Line(|l| l.contains("Instant::now")),
        },
        Rule {
            id: "panic-in-dispatch",
            message: "panic path (unwrap/expect/panic!/unreachable!) inside a coordinator \
                      dispatch fn turns one bad request into a corrupted worker turn — \
                      return the error (let-else / Option) or justify the invariant in \
                      allow.list",
            scopes: &["coordinator/scheduler.rs", "coordinator/service.rs"],
            skip_tests: true,
            kind: RuleKind::DispatchLine(panic_tokens),
        },
        Rule {
            id: "index-in-dispatch",
            message: "bare slice indexing in a coordinator dispatch fn is a hidden panic \
                      path — use .get()/let-else, a slice pattern, or justify the bound in \
                      allow.list",
            scopes: &["coordinator/scheduler.rs", "coordinator/service.rs"],
            skip_tests: true,
            kind: RuleKind::DispatchLine(bare_index),
        },
        Rule {
            id: "panic-in-hot-loop",
            message: "panic path inside a solver iteration loop aborts the solve mid-\
                      recurrence — hoist the check out of the loop or fail with \
                      StopReason::Failed",
            scopes: &["solvers/cg.rs", "solvers/pcg.rs", "solvers/defcg.rs", "solvers/blockcg.rs"],
            skip_tests: true,
            kind: RuleKind::HotLoopLine(panic_tokens),
        },
        Rule {
            id: "alloc-in-hot-loop",
            message: "allocation (Vec::new/vec!/clone/collect) inside a solver iteration \
                      loop — preallocate scratch outside the loop (sanctioned bounded \
                      stores live in allow.list)",
            scopes: &["solvers/cg.rs", "solvers/pcg.rs", "solvers/defcg.rs", "solvers/blockcg.rs"],
            skip_tests: true,
            kind: RuleKind::HotLoopLine(alloc_tokens),
        },
        Rule {
            id: "matvec-billing",
            message: "operator application in a fn that never touches a matvec counter \
                      (matvecs/col_matvecs/CounterBaseline) — bill the apply or document \
                      the caller that does in allow.list",
            scopes: &["solvers/"],
            skip_tests: true,
            kind: RuleKind::MatvecBilling,
        },
        Rule {
            id: "lossy-cast",
            message: "raw `as f32`/`as f64` cast — route through util::precision \
                      (to_f64/demote/promote) so precision loss is explicit and auditable \
                      ahead of the mixed-precision work",
            scopes: &["solvers/", "linalg/", "benches/", "examples/"],
            skip_tests: true,
            kind: RuleKind::Line(lossy_cast),
        },
    ]
}

/// One `allow.list` entry: `rule path-suffix :: content-substring`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub path_suffix: String,
    pub substring: String,
    /// 1-based line in allow.list (0 for programmatic entries).
    pub line: usize,
}

/// Parsed `allow.list`: `#` comments and blank lines are skipped; every
/// other line must parse, so a typo fails loudly instead of silently
/// allowing nothing.
#[derive(Debug, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let mut entries = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (head, substring) = line
                .split_once("::")
                .ok_or_else(|| format!("allow.list line {}: missing `::`", i + 1))?;
            let mut head_parts = head.split_whitespace();
            let rule = head_parts
                .next()
                .ok_or_else(|| format!("allow.list line {}: missing rule id", i + 1))?;
            let path_suffix = head_parts
                .next()
                .ok_or_else(|| format!("allow.list line {}: missing path suffix", i + 1))?;
            if head_parts.next().is_some() {
                return Err(format!("allow.list line {}: too many fields before `::`", i + 1));
            }
            let substring = substring.trim();
            if substring.is_empty() {
                return Err(format!("allow.list line {}: empty content substring", i + 1));
            }
            entries.push(AllowEntry {
                rule: rule.to_string(),
                path_suffix: path_suffix.to_string(),
                substring: substring.to_string(),
                line: i + 1,
            });
        }
        Ok(Allowlist { entries })
    }

    /// Index of the first entry sanctioning this (rule, file, line).
    pub fn match_idx(&self, rule: &str, path: &str, line_text: &str) -> Option<usize> {
        self.entries.iter().position(|e| {
            e.rule == rule && path.ends_with(&e.path_suffix) && line_text.contains(&e.substring)
        })
    }

    /// Is this (rule, file, line) combination sanctioned?
    pub fn allows(&self, rule: &str, path: &str, line_text: &str) -> bool {
        self.match_idx(rule, path, line_text).is_some()
    }
}

/// One inline `lint:allow(rule)` marker seen during a scan.
#[derive(Debug, Clone)]
pub struct MarkerUse {
    pub path: String,
    pub line: usize,
    pub rule: String,
    /// Did it actually suppress a matching finding this run?
    pub used: bool,
}

/// Suppression bookkeeping across one or more scanned roots, for the
/// stale-suppression check.
#[derive(Debug, Default)]
pub struct SuppressionUse {
    /// Parallel to `Allowlist::entries`.
    pub allow_used: Vec<bool>,
    pub markers: Vec<MarkerUse>,
}

impl SuppressionUse {
    pub fn for_allowlist(allow: &Allowlist) -> SuppressionUse {
        SuppressionUse { allow_used: vec![false; allow.entries.len()], markers: Vec::new() }
    }

    fn record_allow_use(&mut self, idx: usize) {
        if let Some(slot) = self.allow_used.get_mut(idx) {
            *slot = true;
        }
    }

    fn record_marker_use(&mut self, path: &str, line: usize, rule: &str) {
        for m in self.markers.iter_mut() {
            if m.line == line && m.rule == rule && m.path == path {
                m.used = true;
            }
        }
    }
}

/// Accumulated result of scanning one or more roots with one allowlist.
#[derive(Debug)]
pub struct ScanOutcome {
    pub findings: Vec<Finding>,
    pub suppressions: SuppressionUse,
}

impl ScanOutcome {
    pub fn new(allow: &Allowlist) -> ScanOutcome {
        ScanOutcome { findings: Vec::new(), suppressions: SuppressionUse::for_allowlist(allow) }
    }
}

/// Comment/string-stripped view of the source, one entry per line:
/// comments blank out to spaces, string/char contents vanish (their
/// delimiters remain), code passes through verbatim. Rules match on
/// this, so prose *about* a forbidden pattern can never trip one.
pub fn stripped_lines(src: &str) -> Vec<String> {
    let mut out = String::with_capacity(src.len());
    for t in lexer::lex(src) {
        match t.kind {
            lexer::TokKind::Comment => {
                out.extend(t.text.chars().map(|c| if c == '\n' { '\n' } else { ' ' }));
            }
            lexer::TokKind::Str => {
                out.push('"');
                out.extend(t.text.chars().filter(|&c| c == '\n'));
                out.push('"');
            }
            lexer::TokKind::Char => out.push_str("''"),
            _ => out.push_str(t.text),
        }
    }
    out.lines().map(str::to_string).collect()
}

/// Collect every `lint:allow(rule)` marker in the file into the tracker.
fn collect_markers(rel_path: &str, raw_lines: &[&str], use_track: &mut SuppressionUse) {
    for (idx, raw) in raw_lines.iter().enumerate() {
        let mut rest = *raw;
        while let Some(pos) = rest.find("lint:allow(") {
            let tail = &rest[pos + "lint:allow(".len()..];
            if let Some(close) = tail.find(')') {
                use_track.markers.push(MarkerUse {
                    path: rel_path.to_string(),
                    line: idx + 1,
                    rule: tail[..close].trim().to_string(),
                    used: false,
                });
                rest = &tail[close + 1..];
            } else {
                break;
            }
        }
    }
}

/// Names exempt from the billing audit: trait-impl delegation wrappers
/// whose whole body *is* the apply (the counter lives in their caller).
const BILLING_EXEMPT_FNS: &[&str] = &["apply", "apply_block"];

const BILLING_CALL_TOKENS: &[&str] = &[".apply(", ".apply_block("];
const BILLING_COUNTER_TOKENS: &[&str] = &["matvecs", "CounterBaseline"];

/// Lint one file's content. `rel_path` is `/`-separated, relative to the
/// scanned root. Suppression usage is recorded into `use_track`.
pub fn check_content_tracked(
    rel_path: &str,
    content: &str,
    rules: &[Rule],
    allow: &Allowlist,
    use_track: &mut SuppressionUse,
) -> Vec<Finding> {
    let raw_lines: Vec<&str> = content.lines().collect();
    let stripped = stripped_lines(content);
    let file_regions = regions::analyze(content);
    collect_markers(rel_path, &raw_lines, use_track);

    let mut findings = Vec::new();
    let mut suppress = |rule_id: &'static str,
                        raw: &str,
                        line_no: usize,
                        use_track: &mut SuppressionUse|
     -> bool {
        if raw.contains(&format!("lint:allow({rule_id})")) {
            use_track.record_marker_use(rel_path, line_no, rule_id);
            return true;
        }
        if let Some(idx) = allow.match_idx(rule_id, rel_path, raw) {
            use_track.record_allow_use(idx);
            return true;
        }
        false
    };
    let region_of = |info: &regions::LineInfo| -> &'static str {
        if info.in_test {
            "test"
        } else if info.in_loop {
            "loop"
        } else if info.function.is_some() {
            "fn"
        } else {
            "file"
        }
    };

    for rule in rules {
        if !rule.scopes.is_empty() && !rule.scopes.iter().any(|s| rel_path.contains(s)) {
            continue;
        }
        match rule.kind {
            RuleKind::Line(pred) | RuleKind::DispatchLine(pred) | RuleKind::HotLoopLine(pred) => {
                for (idx, line) in stripped.iter().enumerate() {
                    if line.trim().is_empty() {
                        continue;
                    }
                    let info = file_regions.line(idx + 1);
                    if rule.skip_tests && info.in_test {
                        continue;
                    }
                    match rule.kind {
                        RuleKind::HotLoopLine(_) if !info.in_loop => continue,
                        RuleKind::DispatchLine(_) if info.function.is_none() => continue,
                        _ => {}
                    }
                    if !pred(line) {
                        continue;
                    }
                    let raw = raw_lines.get(idx).copied().unwrap_or("");
                    if suppress(rule.id, raw, idx + 1, use_track) {
                        continue;
                    }
                    findings.push(Finding {
                        path: rel_path.to_string(),
                        line: idx + 1,
                        rule: rule.id,
                        message: rule.message,
                        text: raw.trim().to_string(),
                        function: info.function.clone().unwrap_or_default(),
                        region: region_of(&info),
                    });
                }
            }
            RuleKind::MatvecBilling => {
                billing_audit(
                    rel_path,
                    &raw_lines,
                    &stripped,
                    &file_regions,
                    rule,
                    &mut findings,
                    &mut |id, raw, line, track| suppress(id, raw, line, track),
                    use_track,
                );
            }
        }
    }
    findings
}

/// The matvec-billing audit: group lines by their innermost named fn;
/// any fn containing an operator application must also mention a
/// counter somewhere in its body.
#[allow(clippy::too_many_arguments)]
fn billing_audit(
    rel_path: &str,
    raw_lines: &[&str],
    stripped: &[String],
    file_regions: &regions::FileRegions,
    rule: &Rule,
    findings: &mut Vec<Finding>,
    suppress: &mut dyn FnMut(&'static str, &str, usize, &mut SuppressionUse) -> bool,
    use_track: &mut SuppressionUse,
) {
    use std::collections::BTreeMap;
    // fn name → (first call-site line, body mentions counter).
    let mut per_fn: BTreeMap<String, (Option<usize>, bool)> = BTreeMap::new();
    for (idx, line) in stripped.iter().enumerate() {
        let info = file_regions.line(idx + 1);
        if info.in_test {
            continue;
        }
        let Some(name) = info.function else { continue };
        if BILLING_EXEMPT_FNS.contains(&name.as_str()) {
            continue;
        }
        let entry = per_fn.entry(name).or_insert((None, false));
        if entry.0.is_none() && BILLING_CALL_TOKENS.iter().any(|t| line.contains(t)) {
            entry.0 = Some(idx + 1);
        }
        if BILLING_COUNTER_TOKENS.iter().any(|t| line.contains(t)) {
            entry.1 = true;
        }
    }
    for (name, (call_line, billed)) in per_fn {
        let (Some(line_no), false) = (call_line, billed) else { continue };
        let raw = raw_lines.get(line_no - 1).copied().unwrap_or("");
        if suppress(rule.id, raw, line_no, use_track) {
            continue;
        }
        findings.push(Finding {
            path: rel_path.to_string(),
            line: line_no,
            rule: rule.id,
            message: rule.message,
            text: raw.trim().to_string(),
            function: name,
            region: "fn",
        });
    }
}

/// Lint one file's content without suppression tracking (convenience
/// for tests and one-shot callers).
pub fn check_content(
    rel_path: &str,
    content: &str,
    rules: &[Rule],
    allow: &Allowlist,
) -> Vec<Finding> {
    let mut track = SuppressionUse::for_allowlist(allow);
    check_content_tracked(rel_path, content, rules, allow, &mut track)
}

/// After scanning everything, convert unused suppressions into findings:
/// an `allow.list` entry or inline marker that excused nothing this run
/// must be deleted (or the run passed `--allow-stale` mid-refactor).
pub fn stale_suppressions(outcome: &ScanOutcome, allow: &Allowlist) -> Vec<Finding> {
    let mut stale = Vec::new();
    for (idx, entry) in allow.entries.iter().enumerate() {
        if outcome.suppressions.allow_used.get(idx).copied().unwrap_or(false) {
            continue;
        }
        stale.push(Finding {
            path: "allow.list".to_string(),
            line: entry.line,
            rule: "stale-suppression",
            message: "allow.list entry matched nothing this run — delete it (or pass \
                      --allow-stale mid-refactor)",
            text: format!("{} {} :: {}", entry.rule, entry.path_suffix, entry.substring),
            function: String::new(),
            region: "file",
        });
    }
    for m in &outcome.suppressions.markers {
        if m.used {
            continue;
        }
        stale.push(Finding {
            path: m.path.clone(),
            line: m.line,
            rule: "stale-suppression",
            message: "inline lint:allow marker suppressed nothing this run — delete it \
                      (or pass --allow-stale mid-refactor)",
            text: format!("lint:allow({})", m.rule),
            function: String::new(),
            region: "file",
        });
    }
    stale
}

/// Escape a string for a JSON string literal (hand-rolled: the tool is
/// dependency-free on purpose).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable diagnostics: `{"count":N,"findings":[…]}` with rule
/// id, file:line, function name and region kind per finding.
pub fn findings_to_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\"count\":");
    out.push_str(&findings.len().to_string());
    out.push_str(",\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"function\":\"{}\",\
             \"region\":\"{}\",\"message\":\"{}\",\"text\":\"{}\"}}",
            json_escape(f.rule),
            json_escape(&f.path),
            f.line,
            json_escape(&f.function),
            json_escape(f.region),
            json_escape(f.message),
            json_escape(&f.text)
        ));
    }
    out.push_str("]}");
    out
}

/// All `.rs` files under `root`, as `(absolute, root-relative)` pairs,
/// sorted by relative path for deterministic output.
pub fn walk(root: &Path) -> std::io::Result<Vec<(PathBuf, String)>> {
    fn visit(dir: &Path, root: &Path, out: &mut Vec<(PathBuf, String)>) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_dir() {
                visit(&path, root, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push((path, rel));
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    visit(root, root, &mut out)?;
    out.sort_by(|a, b| a.1.cmp(&b.1));
    Ok(out)
}

/// Scan every `.rs` file under `root` into `outcome`, with `prefix`
/// prepended to each relative path (so multi-root scans — `rust/src`,
/// `benches`, `examples` — report repo-relative paths and rule scopes
/// distinguish the roots).
pub fn scan_root(
    root: &Path,
    prefix: &str,
    rules: &[Rule],
    allow: &Allowlist,
    outcome: &mut ScanOutcome,
) -> std::io::Result<()> {
    for (path, rel) in walk(root)? {
        let rel_full = format!("{prefix}{rel}");
        let content = std::fs::read_to_string(&path)?;
        let f =
            check_content_tracked(&rel_full, &content, rules, allow, &mut outcome.suppressions);
        outcome.findings.extend(f);
    }
    Ok(())
}

/// Lint every `.rs` file under `root` with the given rules + allowlist
/// (single-root convenience; no stale-suppression reporting).
pub fn run(root: &Path, rules: &[Rule], allow: &Allowlist) -> std::io::Result<Vec<Finding>> {
    let mut outcome = ScanOutcome::new(allow);
    scan_root(root, "", rules, allow, &mut outcome)?;
    Ok(outcome.findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripped_lines_blank_comments_and_strings() {
        let src = "let x = 1; // partial_cmp\nlet url = \"https://a\"; let y = 2;\n/// doc .unwrap()\nlet s = r#\"raw .unwrap()\"#;";
        let lines = stripped_lines(src);
        assert!(!lines[0].contains("partial_cmp"));
        assert!(lines[0].contains("let x = 1;"));
        assert!(!lines[1].contains("https"));
        assert!(lines[1].contains("let y = 2;"));
        assert!(!lines[2].contains("unwrap"));
        assert!(!lines[3].contains("unwrap"), "raw string contents are data: {}", lines[3]);
    }

    #[test]
    fn stripped_lines_preserve_line_count_across_block_comments() {
        let src = "a\n/* x\n y */\nb\nlet s = \"multi\nline\";\nc";
        let lines = stripped_lines(src);
        assert_eq!(lines.len(), 7);
        assert_eq!(lines[3], "b");
        assert_eq!(lines[6], "c");
    }

    #[test]
    fn findings_carry_file_line_rule_function_and_region() {
        let rules = default_rules();
        let content =
            "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let f = check_content("util/x.rs", content, &rules, &Allowlist::default());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 2);
        assert_eq!(f[0].rule, "float-sort-unwrap");
        assert_eq!(f[0].function, "f");
        assert_eq!(f[0].region, "fn");
        assert!(f[0].to_string().starts_with("util/x.rs:2: [float-sort-unwrap]"));
    }

    #[test]
    fn inline_marker_suppresses_and_is_tracked() {
        let rules = default_rules();
        let content =
            "let g = m.lock().unwrap(); // lint:allow(bare-lock-unwrap) poisoning on purpose\n";
        let allow = Allowlist::default();
        let mut track = SuppressionUse::for_allowlist(&allow);
        assert!(check_content_tracked("a.rs", content, &rules, &allow, &mut track).is_empty());
        assert_eq!(track.markers.len(), 1);
        assert!(track.markers[0].used);
        // The marker only covers its own rule.
        let wrong = "let g = m.lock().unwrap(); // lint:allow(float-sort-unwrap)\n";
        let mut track2 = SuppressionUse::for_allowlist(&allow);
        assert_eq!(check_content_tracked("a.rs", wrong, &rules, &allow, &mut track2).len(), 1);
        assert!(!track2.markers[0].used, "marker for the wrong rule is unused (stale)");
    }

    #[test]
    fn allowlist_parses_matches_and_tracks_usage() {
        let a = Allowlist::parse(
            "# comment\n\nrelaxed-ordering coordinator/service.rs :: basis_hint\n",
        )
        .unwrap();
        assert_eq!(a.entries.len(), 1);
        assert_eq!(a.entries[0].line, 3);
        assert!(a.allows(
            "relaxed-ordering",
            "coordinator/service.rs",
            "x.basis_hint.load(Ordering::Relaxed)"
        ));
        assert!(!a.allows("relaxed-ordering", "coordinator/service.rs", "other.load(..)"));
        assert!(!a.allows("float-sort-unwrap", "coordinator/service.rs", "basis_hint"));
        assert!(Allowlist::parse("bad line no separator").is_err());
    }

    #[test]
    fn scoped_rules_ignore_other_files() {
        let rules = default_rules();
        let relaxed = "fn f() { x.load(Ordering::Relaxed); }\n";
        assert!(check_content("runtime/ops.rs", relaxed, &rules, &Allowlist::default()).is_empty());
        assert_eq!(
            check_content("coordinator/service.rs", relaxed, &rules, &Allowlist::default()).len(),
            1
        );
    }

    #[test]
    fn skip_tests_rules_ignore_test_regions() {
        let rules = default_rules();
        let content = "use x;\n#[cfg(test)]\nmod tests {\n    use std::thread;\n}\n";
        assert!(
            check_content("solvers/control.rs", content, &rules, &Allowlist::default()).is_empty()
        );
        // ... but not code before the test region.
        let bad = "use std::thread;\n#[cfg(test)]\nmod tests {}\n";
        assert_eq!(
            check_content("solvers/control.rs", bad, &rules, &Allowlist::default()).len(),
            1
        );
    }

    #[test]
    fn panic_in_dispatch_fires_only_outside_tests() {
        let rules = default_rules();
        let bad = "fn dispatch(&self) {\n    let x = self.q.pop().unwrap();\n}\n";
        let f = check_content("coordinator/scheduler.rs", bad, &rules, &Allowlist::default());
        assert_eq!(f.iter().filter(|f| f.rule == "panic-in-dispatch").count(), 1);
        let test_only =
            "#[cfg(test)]\nmod tests {\n    fn t() {\n        x.pop().unwrap();\n    }\n}\n";
        let f = check_content("coordinator/scheduler.rs", test_only, &rules, &Allowlist::default());
        assert!(f.is_empty(), "{f:#?}");
        // Same tokens in a solver file: not a dispatch path.
        let f = check_content("solvers/strategy.rs", bad, &rules, &Allowlist::default());
        assert!(f.iter().all(|f| f.rule != "panic-in-dispatch"));
    }

    #[test]
    fn bare_index_detection() {
        assert!(bare_index("let x = q[i];"));
        assert!(bare_index("out.push(claimed[0].clone());"));
        assert!(bare_index("f(a)[0]"));
        assert!(bare_index("m[0][1]"));
        assert!(!bare_index("#[derive(Debug)]"));
        assert!(!bare_index("let [a, b] = pair;"));
        assert!(!bare_index("let buf = [0u8; 8];"));
        assert!(!bare_index("let v: Vec<[f64; 4]> = vec![];"));
        assert!(!bare_index("return [a, b];"));
        assert!(!bare_index("vec![0.0; n]"));
    }

    #[test]
    fn hot_loop_rules_fire_only_inside_loops() {
        let rules = default_rules();
        let src = "\
fn solve(n: usize) {
    let pre = Vec::new();
    for i in 0..n {
        let per_iter: Vec<f64> = xs.iter().map(|x| x * 2.0).collect();
        let last = residuals.last().unwrap();
    }
}
";
        let f = check_content("solvers/cg.rs", src, &rules, &Allowlist::default());
        assert_eq!(f.iter().filter(|f| f.rule == "alloc-in-hot-loop").count(), 1, "{f:#?}");
        assert_eq!(f.iter().filter(|f| f.rule == "panic-in-hot-loop").count(), 1);
        assert!(f.iter().all(|x| x.line >= 4), "pre-loop Vec::new must not flag: {f:#?}");
        // Same content in a non-solver file: out of scope.
        let f = check_content("coordinator/recycle_math.rs", src, &rules, &Allowlist::default());
        assert!(f.is_empty());
    }

    #[test]
    fn matvec_billing_audit() {
        let rules = default_rules();
        let unbilled = "\
fn refresh(&mut self, a: &dyn Op) {
    a.apply_block(&self.w, &mut self.aw);
}
";
        let f = check_content("solvers/defcg.rs", unbilled, &rules, &Allowlist::default());
        assert_eq!(f.iter().filter(|f| f.rule == "matvec-billing").count(), 1);
        assert_eq!(f[0].function, "refresh");

        let billed = "\
fn step(&mut self, a: &dyn Op) {
    a.apply(&self.p, &mut self.ap);
    self.matvecs += 1;
}
";
        let f = check_content("solvers/defcg.rs", billed, &rules, &Allowlist::default());
        assert!(f.is_empty(), "{f:#?}");

        // Delegation wrappers named apply/apply_block are exempt.
        let delegate = "\
fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
    self.inner.apply_block(xs, ys);
}
";
        let f = check_content("solvers/algebra.rs", delegate, &rules, &Allowlist::default());
        assert!(f.is_empty(), "{f:#?}");
    }

    #[test]
    fn lossy_cast_rule_scopes_and_fires() {
        let rules = default_rules();
        let bad = "fn f(n: usize) -> f64 { n as f64 }\n";
        for path in ["solvers/strategy.rs", "linalg/mat.rs", "benches/b.rs", "examples/e.rs"] {
            let f = check_content(path, bad, &rules, &Allowlist::default());
            assert_eq!(f.iter().filter(|f| f.rule == "lossy-cast").count(), 1, "{path}");
        }
        // util/ (home of the sanctioned precision module) is out of scope.
        let f = check_content("util/precision.rs", bad, &rules, &Allowlist::default());
        assert!(f.is_empty());
    }

    #[test]
    fn stale_suppressions_are_reported() {
        let allow = Allowlist::parse(
            "relaxed-ordering coordinator/service.rs :: basis_hint\n\
             instant-in-solver solvers/never.rs :: Instant::now\n",
        )
        .unwrap();
        let mut outcome = ScanOutcome::new(&allow);
        let content = "fn f() {\n    h.basis_hint.store(1, Ordering::Relaxed);\n}\n";
        let f = check_content_tracked(
            "coordinator/service.rs",
            content,
            &default_rules(),
            &allow,
            &mut outcome.suppressions,
        );
        assert!(f.is_empty());
        let stale = stale_suppressions(&outcome, &allow);
        assert_eq!(stale.len(), 1, "{stale:#?}");
        assert_eq!(stale[0].rule, "stale-suppression");
        assert_eq!(stale[0].line, 2);
        assert!(stale[0].text.contains("solvers/never.rs"));
    }

    #[test]
    fn json_output_is_escaped_and_structured() {
        let f = vec![Finding {
            path: "a.rs".into(),
            line: 3,
            rule: "panic-in-dispatch",
            message: r#"say "no" to panics"#,
            text: "q.pop().unwrap(); // \"why\"".into(),
            function: "dispatch".into(),
            region: "fn",
        }];
        let j = findings_to_json(&f);
        assert!(j.starts_with("{\"count\":1,"));
        assert!(j.contains("\"rule\":\"panic-in-dispatch\""));
        assert!(j.contains("\"function\":\"dispatch\""));
        assert!(j.contains(r#"say \"no\" to panics"#));
        assert!(findings_to_json(&[]).contains("\"count\":0"));
    }
}
