//! Repo-invariant lint engine: mechanical enforcement of the rules
//! reviewers previously policed by hand (see DESIGN.md § Correctness
//! tooling for the rule table and rationale).
//!
//! The engine is deliberately text-based, not AST-based: every rule here
//! is a *surface* invariant — "this token sequence must not appear in
//! this region of the tree" — and a line matcher with comment stripping
//! and a test-region heuristic catches exactly that, with zero
//! dependencies and sub-second runtime. Anything needing type knowledge
//! (e.g. "is this `sort_by` on floats?") is written so the cheap
//! approximation over-approximates and the `allow.list` carries the
//! sanctioned exceptions; every suppression is a reviewed line in that
//! file rather than an invisible non-match.
//!
//! Escape hatches, in precedence order:
//!
//! 1. an inline `lint:allow(rule-id)` marker anywhere on the raw line
//!    (for one-off sites whose justification belongs next to the code);
//! 2. an `allow.list` entry `rule-id path-suffix :: substring` (for
//!    policy-level exceptions, reviewed centrally);
//! 3. `skip_tests` rules ignore everything from the conventional
//!    `#[cfg(test)] mod tests` trailer to end-of-file.

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint rule: a line predicate plus where it applies.
pub struct Rule {
    /// Stable kebab-case identifier (used in `allow.list` and in the
    /// inline `lint:allow(...)` marker).
    pub id: &'static str,
    /// One-line explanation printed with every finding, stating the fix.
    pub message: &'static str,
    /// Path substrings (with `/` separators, relative to the scanned
    /// root) this rule applies to; empty = the whole tree.
    pub scopes: &'static [&'static str],
    /// Skip the trailing `#[cfg(test)] mod tests` region of each file.
    pub skip_tests: bool,
    /// Line predicate, applied to comment-stripped line content.
    pub matches: fn(&str) -> bool,
}

/// One rule violation at a specific `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: &'static str,
    /// The offending line, trimmed (for the human reading the log).
    pub text: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}\n    {}",
            self.path, self.line, self.rule, self.message, self.text
        )
    }
}

/// The repo's rule set. IDs are load-bearing: `allow.list`, inline
/// markers and the self-test fixtures all refer to them.
pub fn default_rules() -> Vec<Rule> {
    vec![
        Rule {
            id: "float-sort-unwrap",
            message: "float comparison via partial_cmp(..).unwrap() panics on NaN — \
                      use total_cmp (and decide where NaN should sort)",
            scopes: &[],
            skip_tests: false,
            matches: |l| l.contains("partial_cmp") && l.contains(".unwrap()"),
        },
        Rule {
            id: "bare-lock-unwrap",
            message: "bare .lock()/.read()/.write().unwrap() poisons the caller after a \
                      panic elsewhere — use util::sync::lock_unpoisoned (it recovers and \
                      logs the call site)",
            scopes: &[],
            skip_tests: false,
            matches: |l| {
                l.contains(".lock().unwrap()")
                    || l.contains(".read().unwrap()")
                    || l.contains(".write().unwrap()")
            },
        },
        Rule {
            id: "relaxed-ordering",
            message: "Ordering::Relaxed on coordinator state read by snapshot() breaks the \
                      busy ≤ span × workers invariant — use SeqCst (advisory hints go in \
                      allow.list)",
            scopes: &["coordinator/scheduler.rs", "coordinator/service.rs"],
            skip_tests: true,
            matches: |l| l.contains("Ordering::Relaxed"),
        },
        Rule {
            id: "std-sync-in-shimmed",
            message: "shimmed modules must reach sync/thread primitives through util::sync \
                      so the loom build model-checks the shipped code",
            scopes: &["coordinator/scheduler.rs", "coordinator/service.rs", "solvers/control.rs"],
            skip_tests: true,
            matches: |l| l.contains("std::sync") || l.contains("std::thread"),
        },
        Rule {
            id: "instant-in-solver",
            message: "Instant::now() inside solver code is a per-iteration syscall in the hot \
                      loop — time at kernel entry only (sanctioned sites live in allow.list)",
            scopes: &["solvers/"],
            skip_tests: true,
            matches: |l| l.contains("Instant::now"),
        },
    ]
}

/// One `allow.list` entry: `rule path-suffix :: content-substring`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub path_suffix: String,
    pub substring: String,
}

/// Parsed `allow.list`: `#` comments and blank lines are skipped; every
/// other line must parse, so a typo fails loudly instead of silently
/// allowing nothing.
#[derive(Debug, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let mut entries = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (head, substring) = line
                .split_once("::")
                .ok_or_else(|| format!("allow.list line {}: missing `::`", i + 1))?;
            let mut head_parts = head.split_whitespace();
            let rule = head_parts
                .next()
                .ok_or_else(|| format!("allow.list line {}: missing rule id", i + 1))?;
            let path_suffix = head_parts
                .next()
                .ok_or_else(|| format!("allow.list line {}: missing path suffix", i + 1))?;
            if head_parts.next().is_some() {
                return Err(format!("allow.list line {}: too many fields before `::`", i + 1));
            }
            let substring = substring.trim();
            if substring.is_empty() {
                return Err(format!("allow.list line {}: empty content substring", i + 1));
            }
            entries.push(AllowEntry {
                rule: rule.to_string(),
                path_suffix: path_suffix.to_string(),
                substring: substring.to_string(),
            });
        }
        Ok(Allowlist { entries })
    }

    /// Is this (rule, file, line) combination sanctioned?
    pub fn allows(&self, rule: &str, path: &str, line_text: &str) -> bool {
        self.entries.iter().any(|e| {
            e.rule == rule && path.ends_with(&e.path_suffix) && line_text.contains(&e.substring)
        })
    }
}

/// Strip comments and string-literal *contents* from one line of Rust
/// source: `//` inside a string (e.g. a URL) does not truncate, `"`
/// inside a char literal or comment does not open a string, and what a
/// string says is data, not code. `in_block` carries `/* ... */` state
/// across lines. The result is what rules match on, so prose *about* a
/// forbidden pattern — doc comments in `ritz.rs` discuss the old
/// `partial_cmp` sort, log messages may quote an API — can never trip a
/// rule.
pub fn strip_comments(line: &str, in_block: &mut bool) -> String {
    let bytes = line.as_bytes();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    let mut in_string = false;
    while i < bytes.len() {
        if *in_block {
            if bytes[i] == b'*' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
                *in_block = false;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        let c = bytes[i];
        if in_string {
            if c == b'\\' && i + 1 < bytes.len() {
                i += 2;
                continue;
            }
            if c == b'"' {
                out.push('"');
                in_string = false;
            }
            i += 1;
            continue;
        }
        match c {
            b'"' => {
                in_string = true;
                out.push('"');
                i += 1;
            }
            b'\'' => {
                // Char literal ('x', '\n', '\'') vs lifetime ('a in
                // generics): a literal closes within a few bytes; a
                // lifetime has no closing quote. Only literals may
                // contain `"` or `/`, so only they need skipping.
                let lit_len = if i + 2 < bytes.len() && bytes[i + 1] == b'\\' {
                    // '\x' escape forms; find the closing quote.
                    bytes[i + 2..].iter().take(6).position(|&b| b == b'\'').map(|p| p + 3)
                } else if i + 2 < bytes.len() && bytes[i + 2] == b'\'' {
                    Some(3)
                } else {
                    None
                };
                match lit_len {
                    Some(len) => {
                        for &b in &bytes[i..i + len] {
                            out.push(b as char);
                        }
                        i += len;
                    }
                    None => {
                        out.push('\'');
                        i += 1;
                    }
                }
            }
            b'/' if i + 1 < bytes.len() && bytes[i + 1] == b'/' => break,
            b'/' if i + 1 < bytes.len() && bytes[i + 1] == b'*' => {
                *in_block = true;
                i += 2;
            }
            _ => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    out
}

/// First line (0-based) of the conventional trailing test region: a
/// `#[cfg(test)]` / `#[cfg(all(test, ...))]` attribute. Everything from
/// there to EOF is "tests" for `skip_tests` rules — the repo keeps unit
/// tests in one trailing `mod tests` per file, which this leans on.
pub fn test_region_start(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| {
            let t = l.trim_start();
            t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test")
        })
        .unwrap_or(lines.len())
}

/// Lint one file's content. `rel_path` is `/`-separated, relative to the
/// scanned root.
pub fn check_content(
    rel_path: &str,
    content: &str,
    rules: &[Rule],
    allow: &Allowlist,
) -> Vec<Finding> {
    let lines: Vec<&str> = content.lines().collect();
    let test_start = test_region_start(&lines);
    let mut in_block = false;
    let mut findings = Vec::new();
    for (idx, raw) in lines.iter().enumerate() {
        let stripped = strip_comments(raw, &mut in_block);
        if stripped.trim().is_empty() {
            continue;
        }
        for rule in rules {
            if !rule.scopes.is_empty() && !rule.scopes.iter().any(|s| rel_path.contains(s)) {
                continue;
            }
            if rule.skip_tests && idx >= test_start {
                continue;
            }
            if !(rule.matches)(&stripped) {
                continue;
            }
            // The inline marker lives in a comment, so consult the RAW line.
            if raw.contains(&format!("lint:allow({})", rule.id)) {
                continue;
            }
            if allow.allows(rule.id, rel_path, raw) {
                continue;
            }
            findings.push(Finding {
                path: rel_path.to_string(),
                line: idx + 1,
                rule: rule.id,
                message: rule.message,
                text: raw.trim().to_string(),
            });
        }
    }
    findings
}

/// All `.rs` files under `root`, as `(absolute, root-relative)` pairs,
/// sorted by relative path for deterministic output.
pub fn walk(root: &Path) -> std::io::Result<Vec<(PathBuf, String)>> {
    fn visit(dir: &Path, root: &Path, out: &mut Vec<(PathBuf, String)>) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_dir() {
                visit(&path, root, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .expect("walked path is under root")
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push((path, rel));
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    visit(root, root, &mut out)?;
    out.sort_by(|a, b| a.1.cmp(&b.1));
    Ok(out)
}

/// Lint every `.rs` file under `root` with the given rules + allowlist.
pub fn run(root: &Path, rules: &[Rule], allow: &Allowlist) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for (path, rel) in walk(root)? {
        let content = std::fs::read_to_string(&path)?;
        findings.extend(check_content(&rel, &content, rules, allow));
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_comments_and_string_contents() {
        let mut blk = false;
        assert_eq!(strip_comments("let x = 1; // partial_cmp", &mut blk), "let x = 1; ");
        // A `//` inside a string does not truncate the line, and the
        // string's contents are blanked (data, not code).
        assert_eq!(
            strip_comments(r#"let url = "https://a"; let y = 2;"#, &mut blk),
            r#"let url = ""; let y = 2;"#
        );
        assert_eq!(
            strip_comments(r#"log("uses partial_cmp(x).unwrap()");"#, &mut blk),
            r#"log("");"#
        );
        assert_eq!(strip_comments("/// partial_cmp(..).unwrap()", &mut blk), "");
    }

    #[test]
    fn strips_block_comments_across_lines() {
        let mut blk = false;
        assert_eq!(strip_comments("a /* partial_cmp", &mut blk), "a ");
        assert!(blk);
        assert_eq!(strip_comments(".unwrap() */ b", &mut blk), " b");
        assert!(!blk);
    }

    #[test]
    fn char_literal_quote_does_not_open_string() {
        let mut blk = false;
        // The '"' char literal must not swallow the // comment.
        assert_eq!(
            strip_comments(r#"if c == '"' { x(); } // note"#, &mut blk),
            r#"if c == '"' { x(); } "#
        );
        // Lifetimes are not char literals.
        assert_eq!(
            strip_comments("fn f<'a>(x: &'a str) {} // c", &mut blk),
            "fn f<'a>(x: &'a str) {} "
        );
    }

    #[test]
    fn test_region_is_detected() {
        let lines = vec!["fn a() {}", "#[cfg(test)]", "mod tests {", "}"];
        assert_eq!(test_region_start(&lines), 1);
        let gated = vec!["fn a() {}", "#[cfg(all(test, not(loom)))]", "mod tests {"];
        assert_eq!(test_region_start(&gated), 1);
        let none = vec!["fn a() {}"];
        assert_eq!(test_region_start(&none), 1);
    }

    #[test]
    fn allowlist_parses_and_matches() {
        let a = Allowlist::parse(
            "# comment\n\nrelaxed-ordering coordinator/service.rs :: basis_hint\n",
        )
        .unwrap();
        assert_eq!(a.entries.len(), 1);
        assert!(a.allows(
            "relaxed-ordering",
            "coordinator/service.rs",
            "x.basis_hint.load(Ordering::Relaxed)"
        ));
        assert!(!a.allows("relaxed-ordering", "coordinator/service.rs", "other.load(..)"));
        assert!(!a.allows("float-sort-unwrap", "coordinator/service.rs", "basis_hint"));
        assert!(Allowlist::parse("bad line no separator").is_err());
    }

    #[test]
    fn findings_carry_file_line_and_rule() {
        let rules = default_rules();
        let content =
            "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let f = check_content("util/x.rs", content, &rules, &Allowlist::default());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 2);
        assert_eq!(f[0].rule, "float-sort-unwrap");
        assert!(f[0].to_string().starts_with("util/x.rs:2: [float-sort-unwrap]"));
    }

    #[test]
    fn inline_marker_suppresses() {
        let rules = default_rules();
        let content =
            "let g = m.lock().unwrap(); // lint:allow(bare-lock-unwrap) poisoning on purpose\n";
        assert!(check_content("a.rs", content, &rules, &Allowlist::default()).is_empty());
        // The marker only covers its own rule.
        let wrong = "let g = m.lock().unwrap(); // lint:allow(float-sort-unwrap)\n";
        assert_eq!(check_content("a.rs", wrong, &rules, &Allowlist::default()).len(), 1);
    }

    #[test]
    fn scoped_rules_ignore_other_files() {
        let rules = default_rules();
        let relaxed = "x.load(Ordering::Relaxed);\n";
        assert!(check_content("solvers/cg.rs", relaxed, &rules, &Allowlist::default()).is_empty());
        assert_eq!(
            check_content("coordinator/service.rs", relaxed, &rules, &Allowlist::default()).len(),
            1
        );
    }

    #[test]
    fn skip_tests_rules_ignore_trailing_test_mod() {
        let rules = default_rules();
        let content = "use x;\n#[cfg(test)]\nmod tests {\n    use std::thread;\n}\n";
        assert!(
            check_content("solvers/control.rs", content, &rules, &Allowlist::default()).is_empty()
        );
        // ... but not code before the test region.
        let bad = "use std::thread;\n#[cfg(test)]\nmod tests {}\n";
        assert_eq!(
            check_content("solvers/control.rs", bad, &rules, &Allowlist::default()).len(),
            1
        );
    }
}
