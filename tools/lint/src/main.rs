//! `cargo run -p lint` — walk `rust/src`, enforce the repo invariants in
//! `lint::default_rules`, exit non-zero with `file:line` diagnostics on
//! any violation. Sanctioned exceptions live in `tools/lint/allow.list`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    // tools/lint → repo root → rust/src. An explicit argument overrides,
    // so the binary can also lint fixture trees or out-of-repo checkouts.
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| manifest_dir.join("../../rust/src"));
    let allow_path = manifest_dir.join("allow.list");

    let allow = match load_allowlist(&allow_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("lint: {e}");
            return ExitCode::FAILURE;
        }
    };
    let rules = lint::default_rules();
    let findings = match lint::run(&root, &rules, &allow) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("lint: cannot walk {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    if findings.is_empty() {
        println!("lint: {} clean ({} rules)", root.display(), rules.len());
        return ExitCode::SUCCESS;
    }
    for f in &findings {
        eprintln!("{f}");
    }
    eprintln!(
        "lint: {} violation(s). Fix, add `lint:allow(rule-id)` on the line, or add a \
         reviewed entry to {}.",
        findings.len(),
        allow_path.display()
    );
    ExitCode::FAILURE
}

fn load_allowlist(path: &Path) -> Result<lint::Allowlist, String> {
    match std::fs::read_to_string(path) {
        Ok(text) => lint::Allowlist::parse(&text),
        // A missing allow.list is valid (a tree with zero exceptions).
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(lint::Allowlist::default()),
        Err(e) => Err(format!("cannot read {}: {e}", path.display())),
    }
}
