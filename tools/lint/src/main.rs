//! `cargo run -p lint` — walk `rust/src`, `benches` and `examples`,
//! enforce the repo invariants in `lint::default_rules`, exit non-zero
//! with `file:line` diagnostics on any violation, and flag stale
//! suppressions. Sanctioned exceptions live in `tools/lint/allow.list`.
//!
//! Flags:
//!   --json         machine-readable diagnostics on stdout
//!   --allow-stale  tolerate suppressions that matched nothing
//!                  (for branches mid-refactor)
//!   <root>         lint a single explicit tree instead of the repo

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut json = false;
    let mut allow_stale = false;
    let mut explicit_root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--allow-stale" => allow_stale = true,
            flag if flag.starts_with("--") => {
                eprintln!("lint: unknown flag {flag} (expected --json / --allow-stale)");
                return ExitCode::FAILURE;
            }
            root => explicit_root = Some(PathBuf::from(root)),
        }
    }

    // tools/lint → repo root → scan roots. Paths are reported
    // repo-relative (`rust/src/...`) so rule scopes distinguish roots.
    // An explicit argument overrides, so the binary can also lint
    // fixture trees or out-of-repo checkouts.
    let roots: Vec<(PathBuf, &str)> = match &explicit_root {
        Some(r) => vec![(r.clone(), "")],
        None => vec![
            (manifest_dir.join("../../rust/src"), "rust/src/"),
            (manifest_dir.join("../../benches"), "benches/"),
            (manifest_dir.join("../../examples"), "examples/"),
        ],
    };
    let allow_path = manifest_dir.join("allow.list");
    let allow = match load_allowlist(&allow_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("lint: {e}");
            return ExitCode::FAILURE;
        }
    };
    let rules = lint::default_rules();
    let mut outcome = lint::ScanOutcome::new(&allow);
    for (root, prefix) in &roots {
        if let Err(e) = lint::scan_root(root, prefix, &rules, &allow, &mut outcome) {
            eprintln!("lint: cannot walk {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    }
    let mut findings = outcome.findings.clone();
    if !allow_stale {
        findings.extend(lint::stale_suppressions(&outcome, &allow));
    }

    if json {
        println!("{}", lint::findings_to_json(&findings));
        return if findings.is_empty() { ExitCode::SUCCESS } else { ExitCode::FAILURE };
    }
    if findings.is_empty() {
        let scanned =
            roots.iter().map(|(r, _)| r.display().to_string()).collect::<Vec<_>>().join(", ");
        println!("lint: {scanned} clean ({} rules)", rules.len());
        return ExitCode::SUCCESS;
    }
    for f in &findings {
        eprintln!("{f}");
    }
    eprintln!(
        "lint: {} violation(s). Fix, add `lint:allow(rule-id)` on the line, or add a \
         reviewed entry to {}.",
        findings.len(),
        allow_path.display()
    );
    ExitCode::FAILURE
}

fn load_allowlist(path: &Path) -> Result<lint::Allowlist, String> {
    match std::fs::read_to_string(path) {
        Ok(text) => lint::Allowlist::parse(&text),
        // A missing allow.list is valid (a tree with zero exceptions).
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(lint::Allowlist::default()),
        Err(e) => Err(format!("cannot read {}: {e}", path.display())),
    }
}
