//! Brace-tracking region model over the token stream: which function a
//! line belongs to, whether it sits inside a `for`/`while`/`loop` body,
//! and whether it is test-only (`#[test]`, `#[cfg(test)]`,
//! `#[cfg(all(test, …))]` items at any nesting depth).
//!
//! This is a heuristic scope tracker, not a parser: each `{` pushes a
//! scope derived from the markers seen since the last statement
//! boundary (`fn name`, a loop keyword, a test attribute) plus the
//! enclosing scope's flags, and each `}` pops. Closures deliberately do
//! NOT open a function boundary — a panic or allocation inside a
//! closure that runs per iteration bills to the enclosing named fn and
//! loop, which is exactly the attribution the rules want. Known
//! over-approximations (a brace inside a loop-header expression consumes
//! the pending loop marker early) err toward *flagging*, and the escape
//! hatches absorb the rare false positive.

use crate::lexer::{lex, Tok, TokKind};

/// Per-line region facts, 0-indexed by line (line 1 is `lines[0]`).
#[derive(Debug, Clone, Default)]
pub struct LineInfo {
    /// Innermost enclosing named function, if any.
    pub function: Option<String>,
    /// Inside the body of a `for`/`while`/`loop` (any nesting).
    pub in_loop: bool,
    /// Inside a `#[test]` fn or `#[cfg(test)]`-gated item.
    pub in_test: bool,
}

/// One named function's extent (both bounds 1-based, inclusive).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub start_line: usize,
    pub end_line: usize,
    /// The whole fn (signature line through closing brace) was inside a
    /// test region.
    pub in_test: bool,
}

/// Region analysis of one file.
#[derive(Debug, Default)]
pub struct FileRegions {
    pub lines: Vec<LineInfo>,
    pub fns: Vec<FnSpan>,
}

impl FileRegions {
    /// Facts for a 1-based line (out-of-range lines report defaults).
    pub fn line(&self, line_1based: usize) -> LineInfo {
        self.lines.get(line_1based.wrapping_sub(1)).cloned().unwrap_or_default()
    }
}

#[derive(Clone, Default)]
struct Scope {
    fn_idx: Option<usize>,
    in_loop: bool,
    in_test: bool,
}

/// Keywords that may legitimately precede `[` without it being an index
/// expression (array literals/types/patterns): used by the index rule.
pub const NONINDEX_KEYWORDS: &[&str] = &[
    "mut", "dyn", "let", "ref", "in", "return", "break", "else", "move", "box", "impl", "as",
    "const", "static", "become", "yield",
];

pub fn analyze(src: &str) -> FileRegions {
    let toks = lex(src);
    let n_lines = src.lines().count().max(1);
    let mut lines = vec![LineInfo::default(); n_lines];
    let mut fns: Vec<FnSpan> = Vec::new();

    let mut stack: Vec<Scope> = vec![Scope::default()];
    // Markers pending until the `{` (or `;`) that consumes them.
    let mut pending_fn: Option<String> = None;
    let mut pending_loop = false;
    let mut pending_test = false;
    // `for` waits for an `in` before it marks a loop, so `impl T for U`
    // and HRTB `for<'a>` never do.
    let mut for_await_in = false;
    let mut after_fn_kw = false;

    let mark = |lines: &mut [LineInfo], fns: &[FnSpan], scope: &Scope, line: usize| {
        if let Some(info) = lines.get_mut(line - 1) {
            if info.function.is_none() {
                if let Some(idx) = scope.fn_idx {
                    info.function = Some(fns[idx].name.clone());
                }
            }
            info.in_loop |= scope.in_loop;
            info.in_test |= scope.in_test;
        }
    };

    let toks_sig: Vec<&Tok<'_>> = toks
        .iter()
        .filter(|t| !matches!(t.kind, TokKind::Whitespace | TokKind::Comment))
        .collect();

    let mut k = 0usize;
    while k < toks_sig.len() {
        let t = toks_sig[k];
        let top = stack.last().cloned().unwrap_or_default();
        match (t.kind, t.text) {
            (TokKind::Ident, "fn") => {
                after_fn_kw = true;
                mark(&mut lines, &fns, &top, t.line);
            }
            (TokKind::Ident, name) if after_fn_kw => {
                after_fn_kw = false;
                pending_fn = Some(name.strip_prefix("r#").unwrap_or(name).to_string());
                mark(&mut lines, &fns, &top, t.line);
            }
            (TokKind::Ident, "for") => {
                // HRTB `for<'a>` is not a loop; `impl T for U` has no
                // `in`, so simply waiting for `in` excludes it too.
                if !toks_sig.get(k + 1).is_some_and(|n| n.text == "<") {
                    for_await_in = true;
                }
                mark(&mut lines, &fns, &top, t.line);
            }
            (TokKind::Ident, "in") if for_await_in => {
                for_await_in = false;
                pending_loop = true;
                mark(&mut lines, &fns, &top, t.line);
            }
            (TokKind::Ident, "while") | (TokKind::Ident, "loop") => {
                pending_loop = true;
                mark(&mut lines, &fns, &top, t.line);
            }
            (TokKind::Punct, "#") => {
                // Attribute: if it is a test gate, everything the
                // attribute covers (through its item's braces) is test.
                if toks_sig.get(k + 1).is_some_and(|n| n.text == "[") {
                    let (is_test, consumed) = scan_attribute(&toks_sig, k);
                    pending_test |= is_test;
                    mark(&mut lines, &fns, &top, t.line);
                    k = consumed;
                    continue;
                }
                mark(&mut lines, &fns, &top, t.line);
            }
            (TokKind::Punct, ";") => {
                // Statement boundary: a semicolon discharges markers
                // that never found a body (trait fn signatures,
                // attributes on use/static items, `for` in errors).
                pending_fn = None;
                pending_loop = false;
                pending_test = false;
                for_await_in = false;
                after_fn_kw = false;
                mark(&mut lines, &fns, &top, t.line);
            }
            (TokKind::Punct, "{") => {
                let scope = if let Some(name) = pending_fn.take() {
                    let idx = fns.len();
                    fns.push(FnSpan {
                        name,
                        start_line: t.line,
                        end_line: t.line,
                        in_test: top.in_test || pending_test,
                    });
                    Scope {
                        fn_idx: Some(idx),
                        in_loop: false,
                        in_test: top.in_test || pending_test,
                    }
                } else {
                    Scope {
                        fn_idx: top.fn_idx,
                        in_loop: top.in_loop || pending_loop,
                        in_test: top.in_test || pending_test,
                    }
                };
                pending_loop = false;
                pending_test = false;
                for_await_in = false;
                mark(&mut lines, &fns, &scope, t.line);
                stack.push(scope);
            }
            (TokKind::Punct, "}") => {
                mark(&mut lines, &fns, &top, t.line);
                if stack.len() > 1 {
                    let popped = stack.pop().unwrap_or_default();
                    if let Some(idx) = popped.fn_idx {
                        // Only the fn's own closing brace finalizes it.
                        let parent_fn = stack.last().and_then(|s| s.fn_idx);
                        if parent_fn != Some(idx) {
                            if let Some(f) = fns.get_mut(idx) {
                                f.end_line = f.end_line.max(t.line);
                            }
                        }
                    }
                }
            }
            _ => {
                after_fn_kw = false;
                mark(&mut lines, &fns, &top, t.line);
            }
        }
        k += 1;
    }

    // Extend each fn's end line monotonically: any line marked with the
    // fn via `mark` is within its span.
    for (i, info) in lines.iter().enumerate() {
        if let Some(name) = &info.function {
            for f in fns.iter_mut().rev() {
                if &f.name == name && f.start_line <= i + 1 {
                    f.end_line = f.end_line.max(i + 1);
                    break;
                }
            }
        }
    }

    FileRegions { lines, fns }
}

/// Scan the attribute starting at `#` (index `k` into the significant
/// token stream). Returns (is-test-gate, index of the closing `]`).
fn scan_attribute(toks: &[&Tok<'_>], k: usize) -> (bool, usize) {
    // Reconstruct the attribute's significant text to classify it the
    // same way the legacy line heuristic did — `#[test]`,
    // `#[cfg(test…)]`, `#[cfg(all(test…)]`, `#[cfg(any(test…)]` are test
    // gates; `#[cfg(not(test))]` is NOT.
    let mut depth = 0usize;
    let mut text = String::new();
    let mut j = k;
    while j < toks.len() {
        let t = toks[j];
        text.push_str(t.text);
        match t.text {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        j += 1;
    }
    let is_test = text == "#[test]"
        || text.starts_with("#[cfg(test")
        || text.starts_with("#[cfg(all(test")
        || text.starts_with("#[cfg(any(test");
    (is_test, j)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn analyze_lines(src: &str) -> FileRegions {
        analyze(src)
    }

    #[test]
    fn function_attribution_and_loops() {
        let src = "\
fn solve(n: usize) -> f64 {
    let mut acc = 0.0;
    for i in 0..n {
        acc += i as f64;
        while acc > 10.0 {
            acc /= 2.0;
        }
    }
    acc
}
fn other() {}
";
        let r = analyze_lines(src);
        assert_eq!(r.line(2).function.as_deref(), Some("solve"));
        assert!(!r.line(2).in_loop);
        assert!(r.line(4).in_loop);
        assert!(r.line(6).in_loop);
        assert_eq!(r.line(9).function.as_deref(), Some("solve"));
        assert!(!r.line(9).in_loop);
        assert_eq!(r.fns.len(), 2);
        assert_eq!(r.fns[0].name, "solve");
        assert!(r.fns[0].start_line <= 1 && r.fns[0].end_line >= 9);
    }

    #[test]
    fn impl_for_and_hrtb_are_not_loops() {
        let src = "\
impl Clone for Thing {
    fn clone(&self) -> Thing {
        Thing
    }
}
fn hof<F>(f: F) where for<'a> F: Fn(&'a u8) {
    f(&1);
}
";
        let r = analyze_lines(src);
        assert!(!r.line(2).in_loop);
        assert!(!r.line(3).in_loop);
        assert!(!r.line(7).in_loop);
        assert_eq!(r.line(3).function.as_deref(), Some("clone"));
        assert_eq!(r.line(7).function.as_deref(), Some("hof"));
    }

    #[test]
    fn closures_do_not_open_function_boundaries() {
        let src = "\
fn outer() {
    let f = |x: u8| {
        x + 1
    };
    loop {
        let g = move || {
            f(1)
        };
        g();
    }
}
";
        let r = analyze_lines(src);
        assert_eq!(r.line(3).function.as_deref(), Some("outer"));
        assert!(r.line(7).in_loop, "closure body inside loop stays in-loop");
        assert_eq!(r.line(7).function.as_deref(), Some("outer"));
    }

    #[test]
    fn test_regions_at_any_depth() {
        let src = "\
fn shipped() {}
#[cfg(test)]
mod tests {
    fn helper() {}
    #[test]
    fn case() {
        helper();
    }
}
fn also_shipped() {}
";
        let r = analyze_lines(src);
        assert!(!r.line(1).in_test);
        assert!(r.line(4).in_test);
        assert!(r.line(7).in_test);
        assert!(!r.line(10).in_test, "test flag must not leak past the mod");
        let case = r.fns.iter().find(|f| f.name == "case").unwrap();
        assert!(case.in_test);
        let shipped = r.fns.iter().find(|f| f.name == "shipped").unwrap();
        assert!(!shipped.in_test);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "\
#[cfg(not(test))]
fn shipped_only() {
    x();
}
#[cfg(all(test, not(loom)))]
mod gated {
    fn t() {}
}
";
        let r = analyze_lines(src);
        assert!(!r.line(3).in_test);
        assert!(r.line(7).in_test);
    }

    #[test]
    fn trait_method_signatures_do_not_leak_fn_markers() {
        let src = "\
trait T {
    fn sig_only(&self);
    fn with_default(&self) {
        x();
    }
}
";
        let r = analyze_lines(src);
        assert_eq!(r.line(4).function.as_deref(), Some("with_default"));
        // The semicolon discharged `sig_only`; the trait body brace did
        // not become its function.
        assert!(r.fns.iter().all(|f| f.name != "sig_only"));
    }

    #[test]
    fn labeled_loops_and_match_inherit() {
        let src = "\
fn f(xs: &[u8]) -> u8 {
    'outer: for x in xs {
        match x {
            0 => {
                continue 'outer;
            }
            _ => return *x,
        }
    }
    0
}
";
        let r = analyze_lines(src);
        assert!(r.line(5).in_loop, "match arm body inherits loop region");
        assert_eq!(r.line(5).function.as_deref(), Some("f"));
    }
}
