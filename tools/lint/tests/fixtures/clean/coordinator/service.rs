// Fixture: scoped file that must yield ZERO findings — every forbidden
// pattern below is suppressed by a legitimate mechanism.

// 1. Prose about a pattern is stripped before matching:
//    the old code did `v.sort_by(|a, b| a.partial_cmp(b).unwrap())`.

/* Block comments too: counter.load(Ordering::Relaxed) is discussed
here across lines and must not fire. */

use crate::util::sync::atomic::{AtomicU64, Ordering};

// 2. Allowlisted site (the self-test supplies a matching allow entry).
pub fn basis_hint(hint: &AtomicU64) -> u64 {
    hint.load(Ordering::Relaxed) // advisory basis_hint, not snapshot state
}

// 3. Inline marker on the raw line. Two rules match the lock line —
//    the poison rule and the dispatch panic audit — so it carries one
//    marker per rule.
pub fn poisoned_probe(m: &std::sync::Mutex<u64>) -> u64 { // lint:allow(std-sync-in-shimmed)
    *m.lock().unwrap() // lint:allow(bare-lock-unwrap) lint:allow(panic-in-dispatch) fixture
}

// 3b. Dispatch-region rules honour the same markers: panic and bare
//     indexing in a coordinator fn are fine when the invariant is
//     documented at the site.
pub fn first_token(q: &[u64]) -> u64 {
    *q.first().unwrap() // lint:allow(panic-in-dispatch) caller guarantees non-empty
}

pub fn pop_slot(q: &mut Vec<u64>, idx: usize) -> u64 {
    debug_assert!(idx < q.len());
    let v = q[idx]; // lint:allow(index-in-dispatch) bounds asserted above
    q.swap_remove(idx);
    v
}

// 4. A string literal containing a forbidden token is not code.
pub fn doc() -> &'static str {
    "call sites must never use partial_cmp(x).unwrap() on floats"
}

// 5. skip_tests: everything below the test attribute is ignored for
//    scoped rules like relaxed-ordering / std-sync-in-shimmed.
#[cfg(test)]
mod tests {
    use std::thread;

    #[test]
    fn relaxed_is_fine_in_tests() {
        let c = std::sync::atomic::AtomicU64::new(0);
        let _ = c.load(std::sync::atomic::Ordering::Relaxed);
        thread::yield_now();
    }
}
