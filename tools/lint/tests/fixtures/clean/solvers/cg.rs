// Fixture: clean solver file — timing happens once at entry and is
// allowlisted by the self-test, mirroring the real repo policy.
use std::time::Instant;

pub fn solve(n: usize) -> f64 {
    let start = Instant::now();
    let mut acc = 0.0;
    for i in 0..n {
        acc += (i as f64).sqrt();
    }
    let _elapsed = start.elapsed();
    acc
}
