// Fixture: clean solver file — timing happens once at entry and is
// allowlisted by the self-test, mirroring the real repo policy.
use crate::util::precision::to_f64;
use std::time::Instant;

pub fn solve(n: usize) -> f64 {
    let start = Instant::now();
    let mut acc = 0.0;
    for i in 0..n {
        acc += to_f64(i).sqrt();
    }
    let _elapsed = start.elapsed();
    acc
}

// Billing-compliant: the operator application and the counter touch
// live in the same fn, so the matvec audit is satisfied.
pub fn billed_apply(a: &Operator, x: &[f64], y: &mut [f64], stats: &mut Stats) {
    a.apply(x, y);
    stats.matvecs += 1;
}

// A bounded per-iteration snapshot: the clone in the loop is sanctioned
// by an allow entry the self-test supplies (the real repo's `stored.p`
// history stores follow the same pattern).
pub fn checkpoint(cols: &[Vec<f64>], snaps: &mut Vec<Vec<f64>>) {
    for c in cols {
        snaps.push(c.clone());
    }
}
