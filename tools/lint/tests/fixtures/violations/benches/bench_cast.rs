// Fixture: must trip `lossy-cast` under a bench root — the sweep covers
// benches/ and examples/, not just the library tree.
fn throughput(items: usize, secs: f64) -> f64 {
    items as f64 / secs
}
