// Fixture: must trip `std-sync-in-shimmed` (bypasses the loom shim),
// `panic-in-dispatch` (unwrap in a dispatch fn) and `index-in-dispatch`
// (bare slice index in a dispatch fn).
use std::sync::Mutex;

pub fn queue() -> Mutex<Vec<u64>> {
    Mutex::new(Vec::new())
}

pub fn pop_front(q: &mut Vec<u64>) -> u64 {
    q.get(0).copied().unwrap()
}

pub fn peek(q: &[u64]) -> u64 {
    q[0]
}
