// Fixture: must trip `std-sync-in-shimmed` (bypasses the loom shim).
use std::sync::Mutex;

pub fn queue() -> Mutex<Vec<u64>> {
    Mutex::new(Vec::new())
}
