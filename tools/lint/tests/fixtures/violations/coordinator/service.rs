// Fixture: must trip `relaxed-ordering` (snapshot-visible counter).
use crate::util::sync::atomic::{AtomicU64, Ordering};

pub fn read_completed(completed: &AtomicU64) -> u64 {
    completed.load(Ordering::Relaxed)
}
