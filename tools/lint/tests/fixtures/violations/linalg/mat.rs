// Fixture: must trip `lossy-cast` — a raw usize → f64 cast silently
// loses precision past 2^53; util::precision makes the conversion
// explicit and debug-checked.
pub fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len() as f64
}
