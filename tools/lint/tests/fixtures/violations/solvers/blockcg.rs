// Fixture: must trip `alloc-in-hot-loop` — but only for the clone
// inside the loop; the pre-loop Vec::new() is the sanctioned
// preallocation pattern and stays silent.
pub fn iterate(cols: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut out: Vec<Vec<f64>> = Vec::new();
    for c in cols {
        out.push(c.clone());
    }
    out
}
