// Fixture: must trip `instant-in-solver` (clock read inside the loop).
use std::time::Instant;

pub fn iterate(n: usize) -> u128 {
    let mut total = 0;
    for _ in 0..n {
        let t = Instant::now();
        total += t.elapsed().as_nanos();
    }
    total
}
