// Fixture: must trip `std-sync-in-shimmed` via the thread namespace.
pub fn nap() {
    std::thread::yield_now();
}
