// Fixture: must trip `matvec-billing` — the fn applies the operator but
// never touches matvecs/col_matvecs/CounterBaseline, so the work would
// vanish from the paper's cost model.
pub fn probe(a: &Operator, x: &[f64], y: &mut [f64]) {
    a.apply(x, y);
}
