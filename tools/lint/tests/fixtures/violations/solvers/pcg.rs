// Fixture: must trip `panic-in-hot-loop` — the unwrap sits inside the
// iteration loop, so one empty-history edge case aborts the solve.
pub fn iterate(n: usize, residuals: &mut Vec<f64>) -> f64 {
    let mut rel = 1.0;
    for _ in 0..n {
        residuals.push(rel * 0.5);
        rel = *residuals.last().unwrap();
    }
    rel
}
