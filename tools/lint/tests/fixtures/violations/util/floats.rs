// Fixture: must trip `float-sort-unwrap` (NaN panics the comparator).
pub fn sort_scores(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
