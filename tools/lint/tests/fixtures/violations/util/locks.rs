// Fixture: must trip `bare-lock-unwrap` (poison propagates to caller).
use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) {
    *counter.lock().unwrap() += 1;
}
