//! Self-test: the lint must (a) flag every deliberately-violating
//! fixture, (b) stay silent on the clean fixture tree, (c) report
//! suppressions that excuse nothing as stale, and (d) pass on the real
//! swept tree (`rust/src`, `benches`, `examples`) with the checked-in
//! allowlist — so `cargo test -p lint` alone proves the tool both fires
//! and is currently satisfied.

use std::path::PathBuf;

fn fixtures(sub: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(sub)
}

/// The allow entries the clean fixture tree relies on (the fixture
/// files document which line each one excuses).
const CLEAN_ALLOW: &str = "relaxed-ordering coordinator/service.rs :: basis_hint\n\
                           instant-in-solver solvers/cg.rs :: let start = Instant::now();\n\
                           alloc-in-hot-loop solvers/cg.rs :: snaps.push(c.clone());\n";

#[test]
fn every_rule_fires_on_the_violations_tree() {
    let rules = lint::default_rules();
    let findings =
        lint::run(&fixtures("violations"), &rules, &lint::Allowlist::default()).unwrap();

    for rule in &rules {
        assert!(
            findings.iter().any(|f| f.rule == rule.id),
            "rule `{}` produced no finding on the violations fixtures",
            rule.id
        );
    }

    // Each fixture file trips exactly the rule(s) it documents.
    let expected = [
        ("benches/bench_cast.rs", "lossy-cast"),
        ("coordinator/scheduler.rs", "std-sync-in-shimmed"),
        ("coordinator/scheduler.rs", "panic-in-dispatch"),
        ("coordinator/scheduler.rs", "index-in-dispatch"),
        ("coordinator/service.rs", "relaxed-ordering"),
        ("linalg/mat.rs", "lossy-cast"),
        ("solvers/blockcg.rs", "alloc-in-hot-loop"),
        ("solvers/cg.rs", "instant-in-solver"),
        ("solvers/control.rs", "std-sync-in-shimmed"),
        ("solvers/defcg.rs", "matvec-billing"),
        ("solvers/pcg.rs", "panic-in-hot-loop"),
        ("util/floats.rs", "float-sort-unwrap"),
        ("util/locks.rs", "bare-lock-unwrap"),
    ];
    for (path, rule) in expected {
        assert!(
            findings.iter().any(|f| f.path == path && f.rule == rule),
            "expected `{rule}` finding in {path}; got {findings:#?}"
        );
    }
    assert_eq!(findings.len(), expected.len(), "unexpected extra findings: {findings:#?}");

    // Findings point at real lines and carry region context.
    for f in &findings {
        assert!(f.line >= 1);
        assert!(f.to_string().contains(&format!("{}:{}: [{}]", f.path, f.line, f.rule)));
    }
    let by = |rule: &str| findings.iter().find(|f| f.rule == rule).unwrap();
    assert_eq!(by("panic-in-hot-loop").region, "loop");
    assert_eq!(by("alloc-in-hot-loop").region, "loop");
    assert_eq!(by("panic-in-dispatch").function, "pop_front");
    assert_eq!(by("index-in-dispatch").function, "peek");
    assert_eq!(by("matvec-billing").function, "probe");
    // The blockcg fixture's pre-loop Vec::new() must NOT be flagged:
    // only the in-loop clone is a hot-loop allocation.
    assert_eq!(findings.iter().filter(|f| f.rule == "alloc-in-hot-loop").count(), 1);
}

#[test]
fn clean_tree_is_silent_given_its_allow_entries() {
    let rules = lint::default_rules();
    let allow = lint::Allowlist::parse(CLEAN_ALLOW).unwrap();
    let mut outcome = lint::ScanOutcome::new(&allow);
    lint::scan_root(&fixtures("clean"), "", &rules, &allow, &mut outcome).unwrap();
    assert!(outcome.findings.is_empty(), "clean fixtures flagged: {:#?}", outcome.findings);
    // Every suppression — the three entries above AND every inline
    // marker in the clean tree — earned its keep: nothing is stale.
    let stale = lint::stale_suppressions(&outcome, &allow);
    assert!(stale.is_empty(), "stale suppressions on the clean tree: {stale:#?}");
}

#[test]
fn clean_tree_suppressions_are_load_bearing() {
    // Without the allow entries, the clean tree's allowlisted sites
    // resurface — proving the suppression mechanism (not rule scoping)
    // is what keeps them quiet.
    let rules = lint::default_rules();
    let findings = lint::run(&fixtures("clean"), &rules, &lint::Allowlist::default()).unwrap();
    let mut ids: Vec<&str> = findings.iter().map(|f| f.rule).collect();
    ids.sort_unstable();
    assert_eq!(
        ids,
        vec!["alloc-in-hot-loop", "instant-in-solver", "relaxed-ordering"],
        "{findings:#?}"
    );
}

#[test]
fn stale_allow_entry_is_reported() {
    let rules = lint::default_rules();
    // Same entries as the silent-tree test plus one that matches nothing.
    let text = format!("{CLEAN_ALLOW}lossy-cast solvers/cg.rs :: nothing matches this\n");
    let allow = lint::Allowlist::parse(&text).unwrap();
    let mut outcome = lint::ScanOutcome::new(&allow);
    lint::scan_root(&fixtures("clean"), "", &rules, &allow, &mut outcome).unwrap();
    assert!(outcome.findings.is_empty(), "{:#?}", outcome.findings);
    let stale = lint::stale_suppressions(&outcome, &allow);
    assert_eq!(stale.len(), 1, "{stale:#?}");
    assert_eq!(stale[0].rule, "stale-suppression");
    assert_eq!(stale[0].path, "allow.list");
    assert_eq!(stale[0].line, 4, "stale finding points at the allow.list line");
    assert!(stale[0].text.contains("lossy-cast"));
}

#[test]
fn stale_inline_marker_is_reported() {
    let rules = lint::default_rules();
    let allow = lint::Allowlist::default();
    let mut outcome = lint::ScanOutcome::new(&allow);
    let content =
        "pub fn f() -> usize {\n    1 // lint:allow(panic-in-dispatch) excuses nothing\n}\n";
    let findings = lint::check_content_tracked(
        "coordinator/service.rs",
        content,
        &rules,
        &allow,
        &mut outcome.suppressions,
    );
    assert!(findings.is_empty(), "{findings:#?}");
    let stale = lint::stale_suppressions(&outcome, &allow);
    assert_eq!(stale.len(), 1, "{stale:#?}");
    assert_eq!(stale[0].rule, "stale-suppression");
    assert_eq!(stale[0].path, "coordinator/service.rs");
    assert_eq!(stale[0].line, 2);
    assert_eq!(stale[0].text, "lint:allow(panic-in-dispatch)");
}

#[test]
fn json_output_carries_rule_location_function_and_region() {
    let rules = lint::default_rules();
    let content = "pub fn mean(v: &[f64]) -> f64 {\n    v.len() as f64\n}\n";
    let f = lint::check_content("linalg/mat.rs", content, &rules, &lint::Allowlist::default());
    assert_eq!(f.len(), 1, "{f:#?}");
    let json = lint::findings_to_json(&f);
    assert!(json.starts_with("{\"count\":1,\"findings\":["), "{json}");
    for needle in [
        "\"rule\":\"lossy-cast\"",
        "\"path\":\"linalg/mat.rs\"",
        "\"line\":2",
        "\"function\":\"mean\"",
        "\"region\":\"fn\"",
    ] {
        assert!(json.contains(needle), "missing {needle} in {json}");
    }
    // Quotes and backslashes in the offending text are escaped.
    let mut esc = f[0].clone();
    esc.text = "say \"hi\" \\ done".to_string();
    let json = lint::findings_to_json(&[esc]);
    assert!(json.contains("say \\\"hi\\\" \\\\ done"), "{json}");
    // Empty input is still a valid document.
    assert_eq!(lint::findings_to_json(&[]), "{\"count\":0,\"findings\":[]}");
}

#[test]
fn real_tree_passes_with_checked_in_allowlist() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let allow_text = std::fs::read_to_string(manifest.join("allow.list")).unwrap();
    let allow = lint::Allowlist::parse(&allow_text).unwrap();
    let rules = lint::default_rules();
    let mut outcome = lint::ScanOutcome::new(&allow);
    for (dir, prefix) in [
        ("../../rust/src", "rust/src/"),
        ("../../benches", "benches/"),
        ("../../examples", "examples/"),
    ] {
        lint::scan_root(&manifest.join(dir), prefix, &rules, &allow, &mut outcome).unwrap();
    }
    assert!(
        outcome.findings.is_empty(),
        "swept tree violates repo invariants:\n{}",
        outcome.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
    // The checked-in allowlist and every inline marker must still earn
    // their keep — a stale suppression is an error, same as in CI.
    let stale = lint::stale_suppressions(&outcome, &allow);
    assert!(
        stale.is_empty(),
        "stale suppressions:\n{}",
        stale.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn lexer_round_trips_every_swept_file() {
    // Property: lexing is lossless — concatenating token texts rebuilds
    // every file byte-for-byte, and the stripped view keeps line counts,
    // so findings always point at real lines. Checked over the real
    // swept tree, the fixtures, and the lint's own sources.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut checked = 0usize;
    for root in [
        manifest.join("../../rust/src"),
        manifest.join("../../benches"),
        manifest.join("../../examples"),
        manifest.join("src"),
        fixtures("clean"),
        fixtures("violations"),
    ] {
        for (path, rel) in lint::walk(&root).unwrap() {
            let src = std::fs::read_to_string(&path).unwrap();
            let rebuilt: String = lint::lexer::lex(&src).iter().map(|t| t.text).collect();
            assert_eq!(rebuilt, src, "lexer round-trip mismatch in {rel}");
            assert_eq!(
                lint::stripped_lines(&src).len(),
                src.lines().count(),
                "stripped view changed the line count of {rel}"
            );
            checked += 1;
        }
    }
    assert!(checked > 40, "expected to sweep a real tree, checked only {checked} files");
}
