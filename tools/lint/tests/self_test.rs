//! Self-test: the lint must (a) flag every deliberately-violating
//! fixture, (b) stay silent on the clean fixture tree, and (c) pass on
//! the real `rust/src` with the checked-in allowlist — so `cargo test -p
//! lint` alone proves the tool both fires and is currently satisfied.

use std::path::PathBuf;

fn fixtures(sub: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(sub)
}

#[test]
fn every_rule_fires_on_the_violations_tree() {
    let rules = lint::default_rules();
    let findings =
        lint::run(&fixtures("violations"), &rules, &lint::Allowlist::default()).unwrap();

    for rule in &rules {
        assert!(
            findings.iter().any(|f| f.rule == rule.id),
            "rule `{}` produced no finding on the violations fixtures",
            rule.id
        );
    }

    // Each fixture file trips exactly the rule it documents.
    let expected = [
        ("util/floats.rs", "float-sort-unwrap"),
        ("util/locks.rs", "bare-lock-unwrap"),
        ("coordinator/service.rs", "relaxed-ordering"),
        ("coordinator/scheduler.rs", "std-sync-in-shimmed"),
        ("solvers/control.rs", "std-sync-in-shimmed"),
        ("solvers/cg.rs", "instant-in-solver"),
    ];
    for (path, rule) in expected {
        assert!(
            findings.iter().any(|f| f.path == path && f.rule == rule),
            "expected `{rule}` finding in {path}; got {findings:#?}"
        );
    }
    assert_eq!(findings.len(), expected.len(), "unexpected extra findings: {findings:#?}");

    // Findings point at real lines.
    for f in &findings {
        assert!(f.line >= 1);
        assert!(f.to_string().contains(&format!("{}:{}: [{}]", f.path, f.line, f.rule)));
    }
}

#[test]
fn clean_tree_is_silent_given_its_allow_entries() {
    let rules = lint::default_rules();
    let allow = lint::Allowlist::parse(
        "relaxed-ordering coordinator/service.rs :: basis_hint\n\
         instant-in-solver solvers/cg.rs :: let start = Instant::now();\n",
    )
    .unwrap();
    let findings = lint::run(&fixtures("clean"), &rules, &allow).unwrap();
    assert!(findings.is_empty(), "clean fixtures flagged: {findings:#?}");
}

#[test]
fn clean_tree_suppressions_are_load_bearing() {
    // Without the allow entries, the clean tree's two allowlisted sites
    // resurface — proving the suppression mechanism (not rule scoping)
    // is what keeps them quiet.
    let rules = lint::default_rules();
    let findings = lint::run(&fixtures("clean"), &rules, &lint::Allowlist::default()).unwrap();
    let mut ids: Vec<&str> = findings.iter().map(|f| f.rule).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec!["instant-in-solver", "relaxed-ordering"], "{findings:#?}");
}

#[test]
fn real_tree_passes_with_checked_in_allowlist() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.join("../../rust/src");
    let allow_text = std::fs::read_to_string(manifest.join("allow.list")).unwrap();
    let allow = lint::Allowlist::parse(&allow_text).unwrap();
    let findings = lint::run(&root, &lint::default_rules(), &allow).unwrap();
    assert!(
        findings.is_empty(),
        "rust/src violates repo invariants:\n{}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}
